"""Program builders: the jittable functions that aot.py lowers to HLO.

All programs operate on a single flat f32 parameter vector (and a flat
momentum vector of the same length) so the Rust runtime never needs to know
the pytree structure; the manifest records per-leaf offsets for the pieces
Rust *does* introspect (conv weights, BN affine, fc bias).

Program signatures (all shapes static; B = batch, L = #approximable layers,
N = #params):

  train_qat    (p[N], m[N], x, y, lr)                        -> (p', m', metrics[3])
  train_agn    (p[N], m[N], s[L], sm[L], x, y, seed[2],
                lr, lam, sigma_max)                          -> (p', m', s', sm', metrics[5])
  train_approx (p[N], m[N], x, y, lr, luts[L,65536], as[L])  -> (p', m', metrics[3])
  eval         (p[N], x, y)                                  -> metrics[3]
  eval_agn     (p[N], s[L], x, y, seed[2])                   -> metrics[3]
  eval_approx  (p[N], x, y, luts[L,65536], as[L])            -> metrics[3]
  calibrate    (p[N], x, y)                                  -> (absmax[L], ystd[L], metrics[3])

metrics[3] = [loss, correct, topk_correct]; train_agn's metrics[5] =
[total_loss, task_loss, noise_loss, correct, topk_correct].
"""

import jax
import jax.numpy as jnp

from . import losses
from .layers import Ctx

MOMENTUM = 0.9
TOPK = 5


def flatten_params(params):
    """Deterministic flatten; returns (flat, unravel, leaf index).

    The leaf index is a list of (path, offset, shape) in flattening order —
    emitted into the manifest so the Rust side can slice out weights.
    """
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [l for _, l in leaves_with_path]
    shapes = [l.shape for l in leaves]
    sizes = [int(l.size) for l in leaves]
    index = []
    off = 0
    for (path, leaf), size in zip(leaves_with_path, sizes):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        index.append({"path": name, "offset": off, "shape": list(leaf.shape)})
        off += size
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unravel(v):
        out = []
        o = 0
        for shape, size in zip(shapes, sizes):
            out.append(v[o : o + size].reshape(shape))
            o += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unravel, index


def _sgd(flat, mom, grad, lr):
    mom2 = MOMENTUM * mom + grad
    return flat - lr * mom2, mom2


def _metrics3(logits, y, loss):
    return jnp.stack(
        [loss, losses.correct_count(logits, y), losses.topk_correct_count(logits, y, TOPK)]
    )


def make_programs(model, unravel, batch: int):
    """Build the full program dict for `model` (ModelDef) at batch size B."""
    L = len(model.tape)
    rel_costs = model.tape.relative_costs()

    def fwd(flat, x, ctx):
        return model.apply(unravel(flat), x, ctx)

    # -- qat ---------------------------------------------------------------
    def train_qat(flat, mom, x, y, lr):
        def loss_fn(p):
            logits = fwd(p, x, Ctx("qat"))
            return losses.cross_entropy(logits, y), logits

        (loss, logits), grad = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        flat2, mom2 = _sgd(flat, mom, grad, lr)
        return flat2, mom2, _metrics3(logits, y, loss)

    # -- gradient search (paper §3.2) ---------------------------------------
    def train_agn(flat, mom, sig, sig_mom, x, y, seed, lr, lam, sigma_max):
        def loss_fn(p, s):
            logits = fwd(p, x, Ctx("agn", sigmas=s, seed=seed))
            lt = losses.cross_entropy(logits, y)
            ln = losses.noise_loss(s, rel_costs, sigma_max)
            return losses.total_loss(lt, ln, lam), (lt, ln, logits)

        (total, (lt, ln, logits)), (gp, gs) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(flat, sig)
        flat2, mom2 = _sgd(flat, mom, gp, lr)
        sig2, sig_mom2 = _sgd(sig, sig_mom, gs, lr)
        metrics = jnp.stack(
            [total, lt, ln, losses.correct_count(logits, y), losses.topk_correct_count(logits, y, TOPK)]
        )
        return flat2, mom2, sig2, sig_mom2, metrics

    # -- behavioral retraining (paper §4.2, STE) -----------------------------
    def train_approx(flat, mom, x, y, lr, luts, act_scales):
        def loss_fn(p):
            logits = fwd(p, x, Ctx("approx", luts=luts, act_scales=act_scales))
            return losses.cross_entropy(logits, y), logits

        (loss, logits), grad = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        flat2, mom2 = _sgd(flat, mom, grad, lr)
        return flat2, mom2, _metrics3(logits, y, loss)

    # -- evaluation ----------------------------------------------------------
    def eval_qat(flat, x, y):
        logits = fwd(flat, x, Ctx("qat"))
        return _metrics3(logits, y, losses.cross_entropy(logits, y))

    def eval_agn(flat, sig, x, y, seed):
        logits = fwd(flat, x, Ctx("agn", sigmas=sig, seed=seed))
        return _metrics3(logits, y, losses.cross_entropy(logits, y))

    def eval_approx(flat, x, y, luts, act_scales):
        logits = fwd(flat, x, Ctx("approx", luts=luts, act_scales=act_scales))
        return _metrics3(logits, y, losses.cross_entropy(logits, y))

    # -- calibration ---------------------------------------------------------
    def calibrate(flat, x, y):
        ctx = Ctx("calib")
        logits = fwd(flat, x, ctx)
        absmax = jnp.stack(ctx.stat_absmax)
        ystd = jnp.stack(ctx.stat_ystd)
        return absmax, ystd, _metrics3(logits, y, losses.cross_entropy(logits, y))

    h, w, c = model.input_shape
    x_spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    seed_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    lut_spec = jax.ShapeDtypeStruct((L, 256 * 256), jnp.int32)
    asc_spec = jax.ShapeDtypeStruct((L,), jnp.float32)
    sig_spec = jax.ShapeDtypeStruct((L,), jnp.float32)

    def pm(n):
        return jax.ShapeDtypeStruct((n,), jnp.float32)

    return {
        "train_qat": (train_qat, lambda n: (pm(n), pm(n), x_spec, y_spec, scalar)),
        "train_agn": (
            train_agn,
            lambda n: (pm(n), pm(n), sig_spec, sig_spec, x_spec, y_spec, seed_spec, scalar, scalar, scalar),
        ),
        "train_approx": (
            train_approx,
            lambda n: (pm(n), pm(n), x_spec, y_spec, scalar, lut_spec, asc_spec),
        ),
        "eval": (eval_qat, lambda n: (pm(n), x_spec, y_spec)),
        "eval_agn": (eval_agn, lambda n: (pm(n), sig_spec, x_spec, y_spec, seed_spec)),
        "eval_approx": (eval_approx, lambda n: (pm(n), x_spec, y_spec, lut_spec, asc_spec)),
        "calibrate": (calibrate, lambda n: (pm(n), x_spec, y_spec)),
    }
