"""Layer-2 building blocks: quantization-aware layers with AGN / behavioral
approximation modes.

Every approximable layer (conv / depthwise-conv / fc) is registered on a
`Tape` at model-build time, which records the static facts the Rust
coordinator needs (fan-in, multiplication count, operand grid, parameter
offsets). At apply time a `Ctx` selects the mode:

  * ``qat``     — fake-quantized forward (dynamic per-batch scales), STE.
  * ``agn``     — qat forward + learnable AGN on the pre-activation output
                  (paper Eq. 7); noise magnitude ``sigmas[i] * std(y)``.
  * ``approx``  — behavioral simulation: integer codes through the Pallas
                  LUT kernel (frozen activation scales), STE backward
                  through the qat forward.
  * ``calib``   — qat forward, additionally records per-layer activation
                  absmax and pre-activation batch std.

Convolutions are expressed as im2col + matmul so the exact same operand
stream feeds the LUT kernel, the AGN model and the native Rust simulator
(`rust/src/simulator/` mirrors the slice ordering bit-for-bit).
"""

import jax
import jax.numpy as jnp

from .kernels import agn as agn_k
from .kernels import approx_lut as lut_k
from .kernels import matmul as matmul_k
from .kernels import quant as quant_k

_BN_EPS = 1e-5


class Tape:
    """Static registry of approximable layers, built once per model."""

    def __init__(self):
        self.layers = []

    def register(self, **info):
        self.layers.append(info)
        return len(self.layers) - 1

    def __len__(self):
        return len(self.layers)

    def mult_counts(self):
        return [l["mults_per_image"] for l in self.layers]

    def relative_costs(self):
        """c_l = c(l) / sum c(l) — Eq. 10's relative layer cost."""
        counts = jnp.asarray(self.mult_counts(), jnp.float32)
        return counts / jnp.sum(counts)


class Ctx:
    """Per-apply dynamic context (mode, noise params, LUTs, stat sinks)."""

    def __init__(
        self,
        mode: str,
        sigmas=None,
        seed=None,
        luts=None,
        act_scales=None,
        use_pallas_matmul: bool = False,
    ):
        assert mode in ("qat", "agn", "approx", "calib")
        self.mode = mode
        self.sigmas = sigmas
        self.seed = seed
        self.luts = luts
        self.act_scales = act_scales
        self.use_pallas_matmul = use_pallas_matmul
        self.layer_idx = 0
        self.stat_absmax = []
        self.stat_ystd = []

    def next_layer(self):
        i = self.layer_idx
        self.layer_idx = i + 1
        return i

    def layer_seed(self, i):
        """Derive a per-layer seed so layers draw independent noise."""
        s = jnp.asarray(self.seed, jnp.uint32).reshape(2)
        mix = agn_k.hash_u32(s[0] + jnp.uint32(0x9E3779B9) * jnp.uint32(i + 1))
        return jnp.stack([mix, s[1] ^ jnp.uint32(i * 2654435761 & 0xFFFFFFFF)])


# ---------------------------------------------------------------------------
# im2col


def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """x: f32[B, H, W, C] -> patches f32[B, H', W', kh*kw*C].

    Feature ordering is (ki, kj, c) — ki-major — matching both the
    [kh, kw, cin, cout] weight reshape and the Rust simulator.
    """
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = []
    for ki in range(kh):
        for kj in range(kw):
            cols.append(
                x[:, ki : ki + stride * ho : stride, kj : kj + stride * wo : stride, :]
            )
    return jnp.concatenate(cols, axis=-1)


# ---------------------------------------------------------------------------
# quant helpers shared by conv/fc


def _operand_scales(x2d, w2d, ctx, idx, act_signed):
    if ctx.mode == "approx":
        s_x = ctx.act_scales[idx]
    else:
        levels = 127.0 if act_signed else 255.0
        s_x = jnp.maximum(jnp.max(jnp.abs(x2d)), 1e-8) / levels
    s_w = quant_k.weight_scale(w2d)
    return s_x, s_w


def _fq_act(x, s, act_signed):
    if act_signed:
        # signed activation grid [-128, 127]
        return jnp.clip(jnp.round(x / s), -128.0, 127.0) * s
    return quant_k.fake_quant_act(x, s)


def _q_act_codes(x, s, act_signed):
    if act_signed:
        return jnp.clip(jnp.round(x / s), -128.0, 127.0).astype(jnp.int32) + 128
    return quant_k.quantize_act(x, s)


def _approx_forward(x2d, w2d, s_x, s_w, lut, ctx, act_signed, bm=256, bk=64, bn=32):
    """Behavioral LUT forward with STE backward through the fake-quant path."""
    xq = _q_act_codes(x2d, s_x, act_signed)  # row codes in [0, 255]
    wq_off = quant_k.quantize_weight(w2d, s_w) + 128  # col codes in [0, 255]
    acc = lut_k.approx_matmul_lut(xq, wq_off, lut, bm=bm, bk=bk, bn=bn)
    y_approx = acc.astype(jnp.float32) * (s_x * s_w)
    # STE: forward value is the behavioral result, gradient flows through the
    # fake-quantized exact matmul (paper §4.2: STE for AM retraining).
    xf = _fq_act(x2d, s_x, act_signed)
    wf = quant_k.fake_quant_weight(w2d, s_w)
    y_exact = jnp.dot(xf, wf, preferred_element_type=jnp.float32)
    return y_exact + jax.lax.stop_gradient(y_approx - y_exact)


def _qat_forward(x2d, w2d, s_x, s_w, ctx, act_signed):
    xf = _fq_act(x2d, s_x, act_signed)
    wf = quant_k.fake_quant_weight(w2d, s_w)
    if ctx.use_pallas_matmul:
        return matmul_k.matmul_pallas(xf, wf)
    return jnp.dot(xf, wf, preferred_element_type=jnp.float32)


def _maybe_agn(y2d, ctx, idx):
    """Paper Eq. 7 on the flattened pre-activation output."""
    if ctx.mode != "agn":
        return y2d
    scale = ctx.sigmas[idx] * jnp.std(y2d)
    return agn_k.agn_inject(y2d, scale, ctx.layer_seed(idx))


def _record_stats(ctx, x2d, y2d, act_signed):
    if ctx.mode == "calib":
        ctx.stat_absmax.append(jnp.max(jnp.abs(x2d)))
        ctx.stat_ystd.append(jnp.std(y2d))


# ---------------------------------------------------------------------------
# layers


def init_conv(key, cin, cout, k, *, bn=True, bias=False):
    fan_in = k * k * cin
    std = (2.0 / fan_in) ** 0.5
    p = {"w": jax.random.normal(key, (k, k, cin, cout), jnp.float32) * std}
    if bn:
        p["gamma"] = jnp.ones((cout,), jnp.float32)
        p["beta"] = jnp.zeros((cout,), jnp.float32)
    if bias:
        p["b"] = jnp.zeros((cout,), jnp.float32)
    return p


def conv2d(params, x, *, stride, pad, ctx, tape_idx, act_signed=False):
    """Quantized conv via im2col; returns pre-BN, pre-activation output."""
    b, h, w, c = x.shape
    kh, kw, cin, cout = params["w"].shape
    patches = im2col(x, kh, kw, stride, pad)
    ho, wo = patches.shape[1], patches.shape[2]
    x2d = patches.reshape(b * ho * wo, kh * kw * cin)
    w2d = params["w"].reshape(kh * kw * cin, cout)
    s_x, s_w = _operand_scales(x2d, w2d, ctx, tape_idx, act_signed)
    if ctx.mode == "approx":
        y2d = _approx_forward(x2d, w2d, s_x, s_w, ctx.luts[tape_idx], ctx, act_signed)
    else:
        y2d = _qat_forward(x2d, w2d, s_x, s_w, ctx, act_signed)
    _record_stats(ctx, x2d, y2d, act_signed)
    y2d = _maybe_agn(y2d, ctx, tape_idx)
    y = y2d.reshape(b, ho, wo, cout)
    if "b" in params:
        y = y + params["b"]
    return y


def init_dwconv(key, c, k, *, bn=True):
    std = (2.0 / (k * k)) ** 0.5
    p = {"w": jax.random.normal(key, (k, k, c), jnp.float32) * std}
    if bn:
        p["gamma"] = jnp.ones((c,), jnp.float32)
        p["beta"] = jnp.zeros((c,), jnp.float32)
    return p


def dwconv2d(params, x, *, stride, pad, ctx, tape_idx, act_signed=False):
    """Depthwise conv: fan-in k*k (the paper's low-fan-in caveat, §3.3).

    Behavioral mode does a per-tap LUT gather (K is tiny, so the matmul
    kernel's tiling buys nothing here).
    """
    b, h, w, c = x.shape
    kh, kw, cw = params["w"].shape
    patches = im2col(x, kh, kw, stride, pad)  # [B, H', W', kh*kw*C]
    ho, wo = patches.shape[1], patches.shape[2]
    pt = patches.reshape(b, ho, wo, kh * kw, c)
    wt = params["w"].reshape(kh * kw, c)
    flat_x = pt.reshape(-1, kh * kw, c)
    s_x, s_w = _operand_scales(flat_x, wt, ctx, tape_idx, act_signed)
    if ctx.mode == "approx":
        xq = _q_act_codes(flat_x, s_x, act_signed)
        wq_off = quant_k.quantize_weight(wt, s_w) + 128
        idx = xq * lut_k.LUT_SIDE + wq_off[None, :, :]
        prod = jnp.take(ctx.luts[tape_idx], idx.reshape(-1), axis=0).reshape(idx.shape)
        y_approx = prod.sum(axis=1, dtype=jnp.int32).astype(jnp.float32) * (s_x * s_w)
        xf = _fq_act(flat_x, s_x, act_signed)
        wf = quant_k.fake_quant_weight(wt, s_w)
        y_exact = jnp.sum(xf * wf[None, :, :], axis=1)
        y2d = y_exact + jax.lax.stop_gradient(y_approx - y_exact)
    else:
        xf = _fq_act(flat_x, s_x, act_signed)
        wf = quant_k.fake_quant_weight(wt, s_w)
        y2d = jnp.sum(xf * wf[None, :, :], axis=1)
    _record_stats(ctx, flat_x, y2d, act_signed)
    y2d = _maybe_agn(y2d, ctx, tape_idx)
    return y2d.reshape(b, ho, wo, c)


def init_fc(key, cin, cout, *, bias=True):
    std = (2.0 / cin) ** 0.5
    p = {"w": jax.random.normal(key, (cin, cout), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((cout,), jnp.float32)
    return p


def fc(params, x, *, ctx, tape_idx, act_signed=False):
    s_x, s_w = _operand_scales(x, params["w"], ctx, tape_idx, act_signed)
    if ctx.mode == "approx":
        y = _approx_forward(x, params["w"], s_x, s_w, ctx.luts[tape_idx], ctx, act_signed)
    else:
        y = _qat_forward(x, params["w"], s_x, s_w, ctx, act_signed)
    _record_stats(ctx, x, y, act_signed)
    y = _maybe_agn(y, ctx, tape_idx)
    if "b" in params:
        y = y + params["b"]
    return y


# ---------------------------------------------------------------------------
# non-approximable ops


def batchnorm(params, x):
    """Batch-statistics BN (training semantics everywhere; see DESIGN.md)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    inv = params["gamma"] / jnp.sqrt(var + _BN_EPS)
    return (x - mean) * inv + params["beta"]


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def avg_pool(x, k: int, stride: int):
    b, h, w, c = x.shape
    ho, wo = (h - k) // stride + 1, (w - k) // stride + 1
    acc = jnp.zeros((b, ho, wo, c), jnp.float32)
    for ki in range(k):
        for kj in range(k):
            acc = acc + x[:, ki : ki + stride * ho : stride, kj : kj + stride * wo : stride, :]
    return acc / (k * k)


def max_pool(x, k: int, stride: int):
    b, h, w, c = x.shape
    ho, wo = (h - k) // stride + 1, (w - k) // stride + 1
    out = jnp.full((b, ho, wo, c), -jnp.inf, jnp.float32)
    for ki in range(k):
        for kj in range(k):
            out = jnp.maximum(
                out, x[:, ki : ki + stride * ho : stride, kj : kj + stride * wo : stride, :]
            )
    return out


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))
