"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
(pytest + hypothesis sweep shapes, seeds and tables). They are deliberately
written in the most direct jnp form, with none of the tiling/padding of the
kernels.
"""

import jax.numpy as jnp

from .agn import normal_from_counter
from .approx_lut import LUT_SIDE


def matmul_ref(x, w):
    """Plain f32 matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def agn_inject_ref(y, scale, seed):
    """y + scale * q with q regenerated from the same counter PRNG.

    The oracle reproduces the *exact* noise stream (hash + Box-Muller over
    the flat element index) so kernel vs oracle is an equality check, not a
    distribution test. Distributional sanity of the PRNG itself is covered
    by dedicated tests.
    """
    seed = jnp.asarray(seed, jnp.uint32).reshape(2)
    m, n = y.shape
    counter = jnp.arange(m * n, dtype=jnp.uint32).reshape(m, n)
    q = normal_from_counter(counter, seed[0], seed[1])
    return y + jnp.asarray(scale, jnp.float32) * q


def approx_matmul_lut_ref(xq, wq_off, lut):
    """Gather-everything reference of the LUT matmul (no tiling).

    Builds the full [M, K, N] index cube; only usable for small shapes,
    which is exactly what the tests need.
    """
    idx = xq[:, :, None] * LUT_SIDE + wq_off[None, :, :]
    return jnp.take(lut, idx.reshape(-1), axis=0).reshape(idx.shape).sum(
        axis=1, dtype=jnp.int32
    )


def exact_lut(act_signed: bool = False):
    """Product table of the exact 8x8 multiplier under the LUT convention.

    Row = activation code: raw value on the unsigned grid, value+128 on the
    signed grid. Column = weight code + 128 (always signed symmetric).
    """
    a = jnp.arange(LUT_SIDE, dtype=jnp.int32)[:, None]
    if act_signed:
        a = a - 128
    b = jnp.arange(LUT_SIDE, dtype=jnp.int32)[None, :] - 128
    return (a * b).reshape(-1)


def fake_quant_act_ref(x, s):
    return jnp.clip(jnp.round(x / s), 0.0, 255.0) * s


def fake_quant_weight_ref(w, s):
    return jnp.clip(jnp.round(w / s), -127.0, 127.0) * s
