"""Additive-Gaussian-noise injection kernel (paper Eq. 7, Figure 1).

``y_tilde = y + sigma_l * sigma(y) * q``, with ``q ~ N(0, 1)``.

The noise is produced *inside* the kernel by a counter-based hash PRNG
(splitmix/murmur-style finalizer) evaluated per output element and fed
through a Box-Muller transform. This keeps the kernel stateless: the only
randomness input is a ``u32[2]`` seed operand, so the lowered HLO is fully
deterministic given (seed, shape) and the Rust coordinator owns
reproducibility. On a GPU the original toolchain would call curand into a
separate buffer; fusing generation into the epilogue removes that extra
memory pass (DESIGN.md §Hardware adaptation).

``sigma(y)`` — the batch standard deviation of the accurate pre-activation
output — is a global reduction, so it is computed by the caller (L2) and
passed in as a scalar; the kernel applies the element-wise part.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TWO_PI = 6.283185307179586


def hash_u32(x):
    """Murmur3-style 32-bit finalizer; decorrelates consecutive counters."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform01(bits):
    """Map uint32 -> float32 uniform in (0, 1]; never 0 so log() is safe."""
    # Take the top 24 bits -> [0, 2^24), scale to (0, 1].
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / 16777216.0
    ) + jnp.float32(1.0 / 33554432.0)


def normal_from_counter(counter, seed0, seed1):
    """Standard normal from a flat element counter via Box-Muller.

    counter: uint32 array of element indices. seed0/seed1: uint32 scalars.
    """
    c = jnp.asarray(counter, jnp.uint32)
    b1 = hash_u32(c * jnp.uint32(2) + jnp.uint32(1) ^ seed0)
    b2 = hash_u32(c * jnp.uint32(2) ^ seed1)
    u1 = _uniform01(b1)
    u2 = _uniform01(b2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(_TWO_PI * u2)


def _agn_kernel(y_ref, scale_ref, seed_ref, o_ref, *, bm: int, n: int):
    """One grid step over rows: o = y + scale * q(seed, element index)."""
    i = pl.program_id(0)
    base = i.astype(jnp.uint32) * jnp.uint32(bm * n)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (bm, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bm, n), 1)
    counter = base + rows * jnp.uint32(n) + cols
    q = normal_from_counter(counter, seed_ref[0], seed_ref[1])
    o_ref[...] = y_ref[...] + scale_ref[0] * q


def _counter_normal_full(shape, seed):
    """Noise tensor as the kernel generates it (flat row-major counters)."""
    m, n = shape
    seed = jnp.asarray(seed, jnp.uint32).reshape(2)
    counter = jnp.arange(m * n, dtype=jnp.uint32).reshape(m, n)
    return normal_from_counter(counter, seed[0], seed[1])


@jax.custom_vjp
def agn_inject(y, scale, seed):
    """Differentiable AGN injection: y + scale * q(seed).

    Forward runs the Pallas kernel; backward is the analytic paper Eq. 9:
    dL/dy = g, dL/dscale = <g, q> with q regenerated from the counter PRNG
    (cheaper than saving the noise tensor as a residual).
    """
    return _agn_inject_impl(y, scale, seed)


def _agn_fwd(y, scale, seed):
    return _agn_inject_impl(y, scale, seed), (y.shape, seed)


def _agn_bwd(res, g):
    shape, seed = res
    q = _counter_normal_full(shape, seed)
    return g, jnp.sum(g * q), None


agn_inject.defvjp(_agn_fwd, _agn_bwd)


@functools.partial(jax.jit, static_argnames=("bm",))
def _agn_inject_impl(y, scale, seed, *, bm: int = 1024):
    """Perturb ``y`` (f32[M, N]) with AGN of std ``scale`` (f32 scalar).

    ``scale`` is ``sigma_l * sigma(y_batch)`` computed by the caller; ``seed``
    is u32[2]. Grid iterates over row blocks only (the kernel is elementwise,
    so a [bm, N] block keeps the interpret-mode grid short while a real-TPU
    build would simply pick bm for VMEM residency).
    """
    m0, n = y.shape
    pad = (-m0) % bm
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)))
    m = y.shape[0]
    scale_v = jnp.reshape(jnp.asarray(scale, jnp.float32), (1,))
    seed_v = jnp.asarray(seed, jnp.uint32).reshape(2)
    out = pl.pallas_call(
        functools.partial(_agn_kernel, bm=bm, n=n),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY)
            if hasattr(pl, "ANY")
            else pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pl.ANY)
            if hasattr(pl, "ANY")
            else pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(y, scale_v, seed_v)
    return out[:m0]
