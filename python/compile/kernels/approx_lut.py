"""Behavioral simulation of approximate multipliers as a Pallas LUT matmul.

This is the role TFApprox/ProxSim play in the original (GPU) toolchain: an
int8 matmul whose per-element product is replaced by a lookup in the
multiplier's full 256x256 product table.

TPU mapping (DESIGN.md §Hardware adaptation): the table (256 KiB as i32) is
small enough to stay resident in VMEM for the whole kernel, next to the
streamed operand tiles — the moral equivalent of the CUDA texture cache the
GPU implementation relies on. The lookup is a vectorized gather on the
flattened table; accumulation is exact i32 so the behavioral semantics match
the native Rust simulator bit-for-bit.

LUT convention (shared with rust/src/multipliers/ and simulator/):
    lut[a * 256 + b] = approx_product(x = a, w = b - 128)
with activation codes a in [0, 255] (unsigned, post-ReLU activations) and
weight codes w in [-128, 127] stored offset-by-128. The table contains the
*full approximate product* (exact product + multiplier error), so the same
kernel serves any multiplier — the hardware instance is data, not code.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LUT_SIDE = 256
LUT_SIZE = LUT_SIDE * LUT_SIDE


def _approx_kernel(xq_ref, wq_ref, lut_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step: o += gather(lut, xq_tile x wq_tile).sum(k).

    xq tile: i32[bm, bk] activation codes in [0, 255].
    wq tile: i32[bk, bn] offset weight codes in [0, 255].
    The [bm, bk, bn] index cube is the VMEM-bounding term; block shapes are
    chosen so bm*bk*bn*4 bytes stays far below the VMEM budget.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = xq_ref[...][:, :, None] * LUT_SIDE + wq_ref[...][None, :, :]
    prod = jnp.take(lut_ref[...], idx.reshape(-1), axis=0).reshape(idx.shape)
    o_ref[...] += jnp.sum(prod, axis=1, dtype=jnp.int32)


def _pad_to(x, m, axis, value=0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def approx_matmul_lut(xq, wq_off, lut, *, bm: int = 256, bk: int = 64, bn: int = 32):
    """Approximate int8 matmul: i32[M, N] accumulator of lut lookups.

    xq:     i32[M, K] activation codes in [0, 255].
    wq_off: i32[K, N] weight codes + 128, in [0, 255].
    lut:    i32[65536] full product table of the simulated multiplier.

    Padding uses activation code 0 and weight code 128 (= weight 0); the LUT
    is required to map both to a zero product (true for every multiplier in
    the catalog — checked by `rust/src/multipliers/` tests — and asserted by
    the pytest oracle), so padded cells contribute nothing.
    """
    m0, k0 = xq.shape
    k0w, n0 = wq_off.shape
    assert k0 == k0w, f"inner dims mismatch: {xq.shape} @ {wq_off.shape}"
    xq = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wq_off = _pad_to(_pad_to(wq_off, bk, 0, value=128), bn, 1, value=128)
    m, k = xq.shape
    n = wq_off.shape[1]
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_approx_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            # Full table every step: resident in VMEM on TPU.
            pl.BlockSpec((LUT_SIZE,), lambda i, j, l: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(xq, wq_off, lut)
    return out[:m0, :n0]
