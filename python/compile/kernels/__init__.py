"""Layer-1 Pallas kernels for agn-approx.

All kernels are authored with ``interpret=True`` so they lower to plain HLO
ops executable on the CPU PJRT client (real-TPU lowering would emit Mosaic
custom-calls the CPU plugin cannot run; see DESIGN.md §Hardware adaptation).
"""

from .matmul import matmul_pallas
from .agn import agn_inject, hash_u32, normal_from_counter
from .approx_lut import approx_matmul_lut, LUT_SIDE, LUT_SIZE
from .quant import fake_quant_act, fake_quant_weight, quantize_act, quantize_weight

__all__ = [
    "matmul_pallas",
    "agn_inject",
    "hash_u32",
    "normal_from_counter",
    "approx_matmul_lut",
    "LUT_SIDE",
    "LUT_SIZE",
    "fake_quant_act",
    "fake_quant_weight",
    "quantize_act",
    "quantize_weight",
]
