"""Tiled Pallas matmul.

A standard MXU-tiled matmul kernel. On a real TPU the (bm, bk, bn) tiles
stream HBM->VMEM via the BlockSpec index maps and the inner ``dot`` maps to
the 128x128 systolic array; under ``interpret=True`` the same schedule runs
as XLA ops so it is executable on the CPU PJRT client.

The training path of the models defaults to ``jnp.dot`` (XLA's native matmul)
for throughput on this CPU-only image; this kernel exists as the
TPU-shaped reference of the schedule and is exercised by the test suite and
by models built with ``use_pallas_matmul=True``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step: o += x_tile @ w_tile.

    The output BlockSpec maps every k step of a given (m, n) to the same
    block, so the accumulator lives in the revisited output tile (the
    classic Pallas accumulate-in-place schedule).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_pallas(x, w, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """``x @ w`` with an explicitly tiled HBM<->VMEM schedule.

    x: f32[M, K], w: f32[K, N] -> f32[M, N]. Shapes are padded up to the
    block sizes; VMEM footprint per grid step is bm*bk + bk*bn + bm*bn
    floats (two operand tiles + the revisited accumulator/output tile).
    """
    m0, k0 = x.shape
    k0w, n0 = w.shape
    assert k0 == k0w, f"inner dims mismatch: {x.shape} @ {w.shape}"
    x = _pad_to(_pad_to(x, bm, 0), bk, 1)
    w = _pad_to(_pad_to(w, bk, 0), bn, 1)
    m, k = x.shape
    n = w.shape[1]
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)
    return out[:m0, :n0]
