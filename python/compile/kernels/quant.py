"""8-bit fake-quantization kernels + STE wrappers.

Quantization grids (shared with rust/src/quant/):
  * activations: unsigned, code = round(x / s_x) clipped to [0, 255],
    s_x = absmax / 255. All approximable layers see post-ReLU (non-negative)
    inputs by construction of the model zoo, so the unsigned grid loses
    nothing and matches the unsigned EvoApprox-style multiplier catalog.
  * weights: signed, code = round(w / s_w) clipped to [-127, 127],
    s_w = absmax / 127 (symmetric; -128 unused, sign-magnitude friendly).

During QAT/gradient-search the scales are *dynamic* (per-batch absmax);
deployment freezes the activation scales via the `calibrate` program
(DESIGN.md §Key design decisions).

The rounding core is a Pallas kernel; the straight-through estimator lives
in the `custom_vjp` wrappers so the backward pass is the identity on the
clipped region, as in standard QAT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACT_LEVELS = 255.0
WEIGHT_LEVELS = 127.0
_EPS = 1e-8


def _round_clip_kernel(x_ref, s_ref, o_ref, *, lo: float, hi: float):
    """o = clip(round(x / s), lo, hi) * s — one elementwise block."""
    s = s_ref[0]
    q = jnp.clip(jnp.round(x_ref[...] / s), lo, hi)
    o_ref[...] = q * s


@functools.partial(jax.jit, static_argnames=("lo", "hi", "bm"))
def _round_clip(x, s, *, lo: float, hi: float, bm: int = 4096):
    flat = x.reshape(-1)
    m0 = flat.shape[0]
    pad = (-m0) % bm
    if pad:
        flat = jnp.pad(flat, (0, pad))
    s_v = jnp.reshape(jnp.asarray(s, jnp.float32), (1,))
    out = pl.pallas_call(
        functools.partial(_round_clip_kernel, lo=lo, hi=hi),
        grid=(flat.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, s_v)
    return out[:m0].reshape(x.shape)


@jax.custom_vjp
def fake_quant_act(x, s):
    """Fake-quantize activations onto the unsigned 8-bit grid with scale s."""
    return _round_clip(x, s, lo=0.0, hi=ACT_LEVELS)


def _fq_act_fwd(x, s):
    return fake_quant_act(x, s), None


def _fq_act_bwd(_, g):
    return g, None  # STE: identity gradient to x, none to the scale


fake_quant_act.defvjp(_fq_act_fwd, _fq_act_bwd)


@jax.custom_vjp
def fake_quant_weight(w, s):
    """Fake-quantize weights onto the signed symmetric 8-bit grid."""
    return _round_clip(w, s, lo=-WEIGHT_LEVELS, hi=WEIGHT_LEVELS)


def _fq_w_fwd(w, s):
    return fake_quant_weight(w, s), None


def _fq_w_bwd(_, g):
    return g, None


fake_quant_weight.defvjp(_fq_w_fwd, _fq_w_bwd)


def act_scale(x):
    """Dynamic activation scale: absmax / 255 (floored away from zero)."""
    return jnp.maximum(jnp.max(jnp.abs(x)), _EPS) / ACT_LEVELS


def weight_scale(w):
    """Weight scale: absmax / 127 (floored away from zero)."""
    return jnp.maximum(jnp.max(jnp.abs(w)), _EPS) / WEIGHT_LEVELS


def quantize_act(x, s):
    """Integer activation codes i32 in [0, 255] (no dequant)."""
    return jnp.clip(jnp.round(x / s), 0.0, ACT_LEVELS).astype(jnp.int32)


def quantize_weight(w, s):
    """Integer weight codes i32 in [-127, 127] (no dequant)."""
    return jnp.clip(jnp.round(w / s), -WEIGHT_LEVELS, WEIGHT_LEVELS).astype(jnp.int32)
