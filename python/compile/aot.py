"""AOT pipeline: lower every (model, program) pair to HLO *text* + manifest.

HLO text is the interchange format — the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProtos (64-bit instruction ids), while the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts --models tinynet,resnet8 \
        [--programs train_agn,eval] [--batch 32]

Each model gets `<model>_<program>.hlo.txt` files plus one
`<model>.manifest.json` describing parameter layout, the approximable-layer
table and per-program I/O, consumed by rust/src/runtime/manifest.rs.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models as M
from . import train as T

DEFAULT_BATCH = 32
SEED = 0


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_desc(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


def _result_desc(fn, specs):
    out = jax.eval_shape(fn, *specs)
    flat, _ = jax.tree_util.tree_flatten(out)
    return [_spec_desc(s) for s in flat]


def export_model(name: str, out_dir: str, batch: int, programs=None, act_signed=False):
    model = M.build_model(name, act_signed=act_signed)
    params = model.init(jax.random.PRNGKey(SEED))
    flat, unravel, leaf_index = T.flatten_params(params)
    n = int(flat.shape[0])
    progs = T.make_programs(model, unravel, batch)
    wanted = programs or list(progs)

    suffix = "_signed" if act_signed else ""
    manifest = {
        "model": name + suffix,
        "arch": name,
        "act_signed": act_signed,
        "batch": batch,
        "input_shape": list(model.input_shape),
        "classes": model.classes,
        "param_count": n,
        "num_layers": len(model.tape),
        "init_seed": SEED,
        "leaves": leaf_index,
        "layers": [dict(l) for l in model.tape.layers],
        "programs": {},
    }
    # initial parameters, so Rust reproduces the same init without python
    init_path = f"{name}{suffix}.init.f32"
    import numpy as np

    np.asarray(flat, dtype=np.float32).tofile(os.path.join(out_dir, init_path))
    manifest["init_params"] = init_path

    for pname in wanted:
        fn, spec_fn = progs[pname]
        specs = spec_fn(n)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}{suffix}_{pname}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["programs"][pname] = {
            "file": fname,
            "inputs": [_spec_desc(s) for s in specs],
            "outputs": _result_desc(fn, specs),
        }
        print(f"  {name}{suffix}/{pname}: {len(text) / 1e6:.2f} MB HLO")

    mpath = os.path.join(out_dir, f"{name}{suffix}.manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {mpath} (N={n}, L={len(model.tape)})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tinynet,resnet8")
    ap.add_argument("--programs", default="")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--signed", action="store_true", help="signed activation grid variant")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    programs = [p for p in args.programs.split(",") if p] or None
    for name in args.models.split(","):
        print(f"[aot] exporting {name} (batch={args.batch})")
        export_model(name, args.out_dir, args.batch, programs, act_signed=args.signed)


if __name__ == "__main__":
    main()
