"""Model zoo (L2): QAT CNNs over the approximable layer primitives.

Each `ModelDef` carries:
  * ``init(key)``            — parameter pytree
  * ``apply(params, x, ctx)``— logits, mode-dependent (see layers.Ctx)
  * ``tape``                 — static registry of approximable layers
  * metadata used by aot.py to emit the Rust-facing manifest

Architectures (paper §4.2/§4.3, scaled per DESIGN.md §Substitutions):
  * tinynet             — 3-layer test model (fast artifact for CI/tests)
  * resnet8/14/20/32    — CIFAR-style 6n+2 ResNet, stages 16/32/64
  * vgg16               — VGG16+BN, width-scaled
  * alexnet             — 5 conv + 3 fc, width-scaled
  * mobilenetv2         — inverted residuals (depthwise = low fan-in case;
                          expansion convs consume signed activations)
"""

import functools

import jax
import jax.numpy as jnp

from . import layers as L


class ModelDef:
    def __init__(self, name, init, apply, tape, input_shape, classes):
        self.name = name
        self.init = init
        self.apply = apply
        self.tape = tape
        self.input_shape = input_shape  # (H, W, C)
        self.classes = classes


def _conv_out(h, k, s, p):
    return (h + 2 * p - k) // s + 1


def _reg_conv(tape, name, cin, cout, k, stride, pad, h, w, act_signed=False):
    ho, wo = _conv_out(h, k, stride, pad), _conv_out(w, k, stride, pad)
    idx = tape.register(
        name=name,
        kind="conv",
        cin=cin,
        cout=cout,
        k=k,
        stride=stride,
        pad=pad,
        in_hw=[h, w],
        out_hw=[ho, wo],
        fan_in=k * k * cin,
        mults_per_image=ho * wo * k * k * cin * cout,
        act_signed=act_signed,
    )
    return idx, ho, wo


def _reg_dwconv(tape, name, c, k, stride, pad, h, w, act_signed=False):
    ho, wo = _conv_out(h, k, stride, pad), _conv_out(w, k, stride, pad)
    idx = tape.register(
        name=name,
        kind="dwconv",
        cin=c,
        cout=c,
        k=k,
        stride=stride,
        pad=pad,
        in_hw=[h, w],
        out_hw=[ho, wo],
        fan_in=k * k,
        mults_per_image=ho * wo * k * k * c,
        act_signed=act_signed,
    )
    return idx, ho, wo


def _reg_fc(tape, name, cin, cout, act_signed=False):
    return tape.register(
        name=name,
        kind="fc",
        cin=cin,
        cout=cout,
        k=1,
        stride=1,
        pad=0,
        in_hw=[1, 1],
        out_hw=[1, 1],
        fan_in=cin,
        mults_per_image=cin * cout,
        act_signed=act_signed,
    )


# ---------------------------------------------------------------------------
# TinyNet


def tinynet(hw=(8, 8), classes=10, width=1.0, act_signed=False):
    h, w = hw
    c1, c2 = max(4, int(8 * width)), max(8, int(16 * width))
    tape = L.Tape()
    i0, h1, w1 = _reg_conv(tape, "conv0", 3, c1, 3, 1, 1, h, w, act_signed)
    i1, h2, w2 = _reg_conv(tape, "conv1", c1, c2, 3, 2, 1, h1, w1, act_signed)
    i2 = _reg_fc(tape, "fc", c2, classes, act_signed)

    def init(key):
        k = jax.random.split(key, 3)
        return {
            "conv0": L.init_conv(k[0], 3, c1, 3),
            "conv1": L.init_conv(k[1], c1, c2, 3),
            "fc": L.init_fc(k[2], c2, classes),
        }

    def apply(params, x, ctx):
        y = L.conv2d(params["conv0"], x, stride=1, pad=1, ctx=ctx, tape_idx=i0, act_signed=act_signed)
        y = L.relu(L.batchnorm(params["conv0"], y))
        y = L.conv2d(params["conv1"], y, stride=2, pad=1, ctx=ctx, tape_idx=i1, act_signed=act_signed)
        y = L.relu(L.batchnorm(params["conv1"], y))
        y = L.global_avg_pool(y)
        return L.fc(params["fc"], y, ctx=ctx, tape_idx=i2, act_signed=act_signed)

    return ModelDef("tinynet", init, apply, tape, (h, w, 3), classes)


# ---------------------------------------------------------------------------
# CIFAR ResNet (6n+2): conv1 + 3 stages x n blocks x 2 convs + fc


def resnet(n: int, hw=(32, 32), classes=10, width=1.0, act_signed=False):
    h, w = hw
    widths = [max(4, int(c * width)) for c in (16, 32, 64)]
    tape = L.Tape()
    spec = []  # (kind, name, meta) in apply order

    i0, ch, cw = _reg_conv(tape, "conv0", 3, widths[0], 3, 1, 1, h, w, act_signed)
    spec.append(("stem", "conv0", i0))
    cin = widths[0]
    for s, cout in enumerate(widths):
        for b in range(n):
            stride = 2 if (s > 0 and b == 0) else 1
            base = f"s{s}b{b}"
            ia, ch2, cw2 = _reg_conv(tape, base + "_conv1", cin, cout, 3, stride, 1, ch, cw, act_signed)
            ib, _, _ = _reg_conv(tape, base + "_conv2", cout, cout, 3, 1, 1, ch2, cw2, act_signed)
            ishort = None
            if stride != 1 or cin != cout:
                ishort, _, _ = _reg_conv(tape, base + "_short", cin, cout, 1, stride, 0, ch, cw, act_signed)
            spec.append(("block", base, (ia, ib, ishort, cin, cout, stride)))
            ch, cw = ch2, cw2
            cin = cout
    ifc = _reg_fc(tape, "fc", widths[2], classes, act_signed)
    spec.append(("fc", "fc", ifc))

    def init(key):
        params = {}
        keys = iter(jax.random.split(key, 4 * len(tape.layers) + 4))
        params["conv0"] = L.init_conv(next(keys), 3, widths[0], 3)
        c_in = widths[0]
        for s, cout in enumerate(widths):
            for b in range(n):
                stride = 2 if (s > 0 and b == 0) else 1
                base = f"s{s}b{b}"
                params[base + "_conv1"] = L.init_conv(next(keys), c_in, cout, 3)
                params[base + "_conv2"] = L.init_conv(next(keys), cout, cout, 3)
                if stride != 1 or c_in != cout:
                    params[base + "_short"] = L.init_conv(next(keys), c_in, cout, 1)
                c_in = cout
        params["fc"] = L.init_fc(next(keys), widths[2], classes)
        return params

    def apply(params, x, ctx):
        y = L.conv2d(params["conv0"], x, stride=1, pad=1, ctx=ctx, tape_idx=i0, act_signed=act_signed)
        y = L.relu(L.batchnorm(params["conv0"], y))
        for kind, base, meta in spec:
            if kind != "block":
                continue
            ia, ib, ishort, c_in, cout, stride = meta
            z = L.conv2d(params[base + "_conv1"], y, stride=stride, pad=1, ctx=ctx, tape_idx=ia, act_signed=act_signed)
            z = L.relu(L.batchnorm(params[base + "_conv1"], z))
            z = L.conv2d(params[base + "_conv2"], z, stride=1, pad=1, ctx=ctx, tape_idx=ib, act_signed=act_signed)
            z = L.batchnorm(params[base + "_conv2"], z)
            if ishort is not None:
                sc = L.conv2d(params[base + "_short"], y, stride=stride, pad=0, ctx=ctx, tape_idx=ishort, act_signed=act_signed)
                sc = L.batchnorm(params[base + "_short"], sc)
            else:
                sc = y
            y = L.relu(z + sc)
        y = L.global_avg_pool(y)
        return L.fc(params["fc"], y, ctx=ctx, tape_idx=ifc, act_signed=act_signed)

    return ModelDef(f"resnet{6 * n + 2}", init, apply, tape, (h, w, 3), classes)


# ---------------------------------------------------------------------------
# VGG16 (+BN), width-scaled


_VGG16_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M"]


def vgg16(hw=(32, 32), classes=20, width=0.25, act_signed=False):
    h, w = hw
    tape = L.Tape()
    convs = []
    cin, ch, cw = 3, h, w
    ci = 0
    for v in _VGG16_CFG:
        if v == "M":
            convs.append(("M", None, None))
            ch, cw = ch // 2, cw // 2
            continue
        cout = max(8, int(v * width))
        idx, ch, cw = _reg_conv(tape, f"conv{ci}", cin, cout, 3, 1, 1, ch, cw, act_signed)
        convs.append(("C", f"conv{ci}", (idx, cin, cout)))
        cin = cout
        ci += 1
    feat = cin * ch * cw
    fdim = max(32, int(256 * width * 2))
    ifc1 = _reg_fc(tape, "fc1", feat, fdim, act_signed)
    ifc2 = _reg_fc(tape, "fc2", fdim, fdim, act_signed)
    ifc3 = _reg_fc(tape, "fc3", fdim, classes, act_signed)

    def init(key):
        params = {}
        keys = iter(jax.random.split(key, len(tape.layers) + 2))
        for kind, name, meta in convs:
            if kind == "C":
                _, c_in, c_out = meta
                params[name] = L.init_conv(next(keys), c_in, c_out, 3)
        params["fc1"] = L.init_fc(next(keys), feat, fdim)
        params["fc2"] = L.init_fc(next(keys), fdim, fdim)
        params["fc3"] = L.init_fc(next(keys), fdim, classes)
        return params

    def apply(params, x, ctx):
        y = x
        for kind, name, meta in convs:
            if kind == "M":
                y = L.max_pool(y, 2, 2)
            else:
                idx, _, _ = meta
                y = L.conv2d(params[name], y, stride=1, pad=1, ctx=ctx, tape_idx=idx, act_signed=act_signed)
                y = L.relu(L.batchnorm(params[name], y))
        y = y.reshape(y.shape[0], -1)
        y = L.relu(L.fc(params["fc1"], y, ctx=ctx, tape_idx=ifc1, act_signed=act_signed))
        y = L.relu(L.fc(params["fc2"], y, ctx=ctx, tape_idx=ifc2, act_signed=act_signed))
        return L.fc(params["fc3"], y, ctx=ctx, tape_idx=ifc3, act_signed=act_signed)

    return ModelDef("vgg16", init, apply, tape, (h, w, 3), classes)


# ---------------------------------------------------------------------------
# AlexNet (CIFAR-scaled)


def alexnet(hw=(32, 32), classes=10, width=0.5, act_signed=False):
    h, w = hw
    cs = [max(8, int(c * width)) for c in (64, 192, 384, 256, 256)]
    tape = L.Tape()
    i0, h1, w1 = _reg_conv(tape, "conv0", 3, cs[0], 3, 1, 1, h, w, act_signed)
    h1, w1 = h1 // 2, w1 // 2  # maxpool
    i1, h2, w2 = _reg_conv(tape, "conv1", cs[0], cs[1], 3, 1, 1, h1, w1, act_signed)
    h2, w2 = h2 // 2, w2 // 2
    i2, h3, w3 = _reg_conv(tape, "conv2", cs[1], cs[2], 3, 1, 1, h2, w2, act_signed)
    i3, h4, w4 = _reg_conv(tape, "conv3", cs[2], cs[3], 3, 1, 1, h3, w3, act_signed)
    i4, h5, w5 = _reg_conv(tape, "conv4", cs[3], cs[4], 3, 1, 1, h4, w4, act_signed)
    h5, w5 = h5 // 2, w5 // 2
    feat = cs[4] * h5 * w5
    fdim = max(64, int(512 * width))
    if1 = _reg_fc(tape, "fc1", feat, fdim, act_signed)
    if2 = _reg_fc(tape, "fc2", fdim, fdim, act_signed)
    if3 = _reg_fc(tape, "fc3", fdim, classes, act_signed)

    def init(key):
        k = iter(jax.random.split(key, 9))
        return {
            "conv0": L.init_conv(next(k), 3, cs[0], 3),
            "conv1": L.init_conv(next(k), cs[0], cs[1], 3),
            "conv2": L.init_conv(next(k), cs[1], cs[2], 3),
            "conv3": L.init_conv(next(k), cs[2], cs[3], 3),
            "conv4": L.init_conv(next(k), cs[3], cs[4], 3),
            "fc1": L.init_fc(next(k), feat, fdim),
            "fc2": L.init_fc(next(k), fdim, fdim),
            "fc3": L.init_fc(next(k), fdim, classes),
        }

    def apply(params, x, ctx):
        y = L.relu(L.batchnorm(params["conv0"], L.conv2d(params["conv0"], x, stride=1, pad=1, ctx=ctx, tape_idx=i0, act_signed=act_signed)))
        y = L.max_pool(y, 2, 2)
        y = L.relu(L.batchnorm(params["conv1"], L.conv2d(params["conv1"], y, stride=1, pad=1, ctx=ctx, tape_idx=i1, act_signed=act_signed)))
        y = L.max_pool(y, 2, 2)
        y = L.relu(L.batchnorm(params["conv2"], L.conv2d(params["conv2"], y, stride=1, pad=1, ctx=ctx, tape_idx=i2, act_signed=act_signed)))
        y = L.relu(L.batchnorm(params["conv3"], L.conv2d(params["conv3"], y, stride=1, pad=1, ctx=ctx, tape_idx=i3, act_signed=act_signed)))
        y = L.relu(L.batchnorm(params["conv4"], L.conv2d(params["conv4"], y, stride=1, pad=1, ctx=ctx, tape_idx=i4, act_signed=act_signed)))
        y = L.max_pool(y, 2, 2)
        y = y.reshape(y.shape[0], -1)
        y = L.relu(L.fc(params["fc1"], y, ctx=ctx, tape_idx=if1, act_signed=act_signed))
        y = L.relu(L.fc(params["fc2"], y, ctx=ctx, tape_idx=if2, act_signed=act_signed))
        return L.fc(params["fc3"], y, ctx=ctx, tape_idx=if3, act_signed=act_signed)

    return ModelDef("alexnet", init, apply, tape, (h, w, 3), classes)


# ---------------------------------------------------------------------------
# MobileNetV2 (scaled). Expansion convs read the (possibly negative) linear
# bottleneck output -> signed activation grid for those layers.


_MBV2_CFG = [  # (expansion t, cout, blocks n, stride)
    (1, 16, 1, 1),
    (6, 24, 2, 1),
    (6, 32, 2, 2),
    (6, 64, 2, 2),
]


def mobilenetv2(hw=(32, 32), classes=10, width=0.5, act_signed=False):
    h, w = hw
    tape = L.Tape()
    blocks = []
    stem_c = max(8, int(32 * width))
    i_stem, ch, cw = _reg_conv(tape, "stem", 3, stem_c, 3, 1, 1, h, w, act_signed)
    cin = stem_c
    bi = 0
    for t, c, n, s in _MBV2_CFG:
        cout = max(8, int(c * width))
        for b in range(n):
            stride = s if b == 0 else 1
            base = f"b{bi}"
            hidden = cin * t
            iexp = None
            if t != 1:
                # expansion input is a linear bottleneck output: signed grid
                iexp, _, _ = _reg_conv(tape, base + "_exp", cin, hidden, 1, 1, 0, ch, cw, act_signed=True)
            idw, ch2, cw2 = _reg_dwconv(tape, base + "_dw", hidden, 3, stride, 1, ch, cw, act_signed)
            iprj, _, _ = _reg_conv(tape, base + "_prj", hidden, cout, 1, 1, 0, ch2, cw2, act_signed)
            blocks.append((base, iexp, idw, iprj, cin, hidden, cout, stride))
            ch, cw = ch2, cw2
            cin = cout
            bi += 1
    head_c = max(16, int(128 * width))
    i_head, _, _ = _reg_conv(tape, "head", cin, head_c, 1, 1, 0, ch, cw, act_signed)
    ifc = _reg_fc(tape, "fc", head_c, classes, act_signed)

    def init(key):
        params = {}
        keys = iter(jax.random.split(key, 4 * len(blocks) + 8))
        params["stem"] = L.init_conv(next(keys), 3, stem_c, 3)
        for base, iexp, idw, iprj, c_in, hidden, cout, stride in blocks:
            if iexp is not None:
                params[base + "_exp"] = L.init_conv(next(keys), c_in, hidden, 1)
            params[base + "_dw"] = L.init_dwconv(next(keys), hidden, 3)
            params[base + "_prj"] = L.init_conv(next(keys), hidden, cout, 1)
        params["head"] = L.init_conv(next(keys), cin, head_c, 1)
        params["fc"] = L.init_fc(next(keys), head_c, classes)
        return params

    def apply(params, x, ctx):
        y = L.relu6(L.batchnorm(params["stem"], L.conv2d(params["stem"], x, stride=1, pad=1, ctx=ctx, tape_idx=i_stem, act_signed=act_signed)))
        for base, iexp, idw, iprj, c_in, hidden, cout, stride in blocks:
            inp = y
            z = y
            if iexp is not None:
                z = L.conv2d(params[base + "_exp"], z, stride=1, pad=0, ctx=ctx, tape_idx=iexp, act_signed=True)
                z = L.relu6(L.batchnorm(params[base + "_exp"], z))
            z = L.dwconv2d(params[base + "_dw"], z, stride=stride, pad=1, ctx=ctx, tape_idx=idw, act_signed=act_signed)
            z = L.relu6(L.batchnorm(params[base + "_dw"], z))
            z = L.conv2d(params[base + "_prj"], z, stride=1, pad=0, ctx=ctx, tape_idx=iprj, act_signed=act_signed)
            z = L.batchnorm(params[base + "_prj"], z)  # linear bottleneck
            if stride == 1 and c_in == cout:
                z = z + inp
            y = z
        y = L.relu6(L.batchnorm(params["head"], L.conv2d(params["head"], y, stride=1, pad=0, ctx=ctx, tape_idx=i_head, act_signed=act_signed)))
        y = L.global_avg_pool(y)
        return L.fc(params["fc"], y, ctx=ctx, tape_idx=ifc, act_signed=act_signed)

    return ModelDef("mobilenetv2", init, apply, tape, (h, w, 3), classes)


# ---------------------------------------------------------------------------
# registry


def build_model(name: str, hw=None, classes=None, width=None, act_signed=False) -> ModelDef:
    """Construct a model by name with optional overrides of the defaults."""
    defaults = {
        "tinynet": dict(fn=tinynet, hw=(8, 8), classes=10, width=1.0),
        "resnet8": dict(fn=functools.partial(resnet, 1), hw=(16, 16), classes=10, width=1.0),
        "resnet14": dict(fn=functools.partial(resnet, 2), hw=(16, 16), classes=10, width=1.0),
        "resnet20": dict(fn=functools.partial(resnet, 3), hw=(16, 16), classes=10, width=1.0),
        "resnet32": dict(fn=functools.partial(resnet, 5), hw=(16, 16), classes=10, width=1.0),
        "vgg16": dict(fn=vgg16, hw=(32, 32), classes=20, width=0.25),
        "alexnet": dict(fn=alexnet, hw=(16, 16), classes=10, width=0.5),
        "mobilenetv2": dict(fn=mobilenetv2, hw=(16, 16), classes=10, width=0.5),
    }
    if name not in defaults:
        raise ValueError(f"unknown model {name!r}; have {sorted(defaults)}")
    d = defaults[name]
    return d["fn"](
        hw=hw or d["hw"],
        classes=classes or d["classes"],
        width=width or d["width"],
        act_signed=act_signed,
    )


MODEL_NAMES = [
    "tinynet",
    "resnet8",
    "resnet14",
    "resnet20",
    "resnet32",
    "vgg16",
    "alexnet",
    "mobilenetv2",
]
