"""Task loss + the paper's noise loss (Eq. 10/11)."""

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def correct_count(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def topk_correct_count(logits, labels, k: int = 5):
    """Top-k correct count (paper reports Top-5 for Tiny ImageNet).

    Formulated as a rank test (count of strictly-larger logits < k) instead
    of `jax.lax.top_k`: the TopK HLO op is newer than the xla_extension
    0.5.1 text parser the Rust runtime links against.
    """
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)
    rank = jnp.sum((logits > label_logit).astype(jnp.int32), axis=-1)
    return jnp.sum((rank < k).astype(jnp.float32))


def noise_loss(sigmas, rel_costs, sigma_max):
    """Paper Eq. 10: L_N = -sum_l min(|sigma_l|, sigma_max) * c_l.

    The gradient w.r.t. sigma_l is -c_l inside the cap and 0 outside
    (Eq. 12), which jnp.minimum's subgradient provides for free.
    """
    capped = jnp.minimum(jnp.abs(sigmas), sigma_max)
    return -jnp.sum(capped * rel_costs)


def total_loss(task, noise, lam):
    """Paper Eq. 11: L = L_T + lambda * L_N."""
    return task + lam * noise
