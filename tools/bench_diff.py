#!/usr/bin/env python3
"""Advisory perf-regression diff between two benchkit JSON exports.

Matches lanes by name and compares p50_s. Lanes present in only one file
are listed but never fail the diff (bench sets grow across PRs). The
default is purely advisory (exit 0 even on regressions) because CI hosts
differ from the committed baseline's host — the embedded `env`
fingerprints are printed so a cross-host comparison is visibly
apples-to-oranges. Pass --strict to turn warnings into exit 1 (only
sensible when both fingerprints match).

Usage:
    bench_diff.py BASELINE.json FRESH.json [--warn-pct 15] [--strict]
"""

import argparse
import json
import sys


def load(path):
    with open(path) as fp:
        doc = json.load(fp)
    lanes = {r["name"]: r for r in doc.get("results", [])}
    return doc, lanes


def fmt_env(doc):
    env = doc.get("env")
    if not env:
        return "(no fingerprint)"
    return ", ".join(f"{k}={env[k]}" for k in sorted(env))


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed benchkit JSON (the reference)")
    ap.add_argument("fresh", help="freshly measured benchkit JSON")
    ap.add_argument("--warn-pct", type=float, default=15.0,
                    help="warn when fresh p50 is this %% slower (default 15)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on warnings instead of staying advisory")
    args = ap.parse_args()

    base_doc, base = load(args.baseline)
    fresh_doc, fresh = load(args.fresh)

    print(f"baseline  {args.baseline}: {fmt_env(base_doc)}")
    print(f"fresh     {args.fresh}: {fmt_env(fresh_doc)}")
    if base_doc.get("env") != fresh_doc.get("env"):
        print("note: fingerprints differ — deltas are cross-host and advisory")
    print()

    common = sorted(set(base) & set(fresh))
    only_base = sorted(set(base) - set(fresh))
    only_fresh = sorted(set(fresh) - set(base))

    warnings = 0
    for name in common:
        b, f = base[name].get("p50_s"), fresh[name].get("p50_s")
        if not b or not f:
            print(f"  ?        {name}: missing p50_s")
            continue
        pct = (f - b) / b * 100.0
        tag = "ok"
        if pct > args.warn_pct:
            tag = "WARN"
            warnings += 1
        elif pct < -args.warn_pct:
            tag = "faster"
        print(f"  {tag:<8} {name}: p50 {b * 1e3:.3f} ms -> {f * 1e3:.3f} ms "
              f"({pct:+.1f}%)")

    for name in only_base:
        print(f"  gone     {name}: in baseline only")
    for name in only_fresh:
        print(f"  new      {name}: in fresh only")

    if not common:
        print("no common lanes — nothing to compare")

    print(f"\n{len(common)} compared, {warnings} over the "
          f"{args.warn_pct:g}% threshold")
    if warnings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
