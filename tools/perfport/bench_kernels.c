/*
 * Offline C port of the rust/benches/bench_simulator.rs kernel-variant
 * lanes, for producing measured BENCH_kernels.json / BENCH_runtime.json
 * numbers on hosts without a Rust toolchain (the build container bakes in
 * only the rust_pallas runtime, not cargo).
 *
 * The ports mirror the Rust kernels loop-for-loop:
 *   - approx_matmul_pool/scalar/t1  -> compute::lut::approx_rows (blocked,
 *     LUT-row-hot (m,k,n) order, wrapping i32 accumulation)
 *   - approx_matmul_pool/simd/t1    -> compute::simd::x86 approx_i32_impl
 *     (_mm256_i32gather_epi32 over the 256-entry LUT row, NB=1024 column
 *     blocks, _mm256_add_epi32 accumulate)
 *   - approx_matmul_pool/simd_i16/t1-> approx_i16_impl (scale-2 gather on
 *     the packed 65537-entry i16 table + slli/srai sign extension, NB=2048)
 *   - gemm/{scalar,simd}/t1         -> compute::gemm row kernel via the
 *     axpy_f32 vtable slot (mul-then-add, deliberately no FMA)
 *
 * Lane names match the Rust bench exactly so tools/bench_diff.py can diff
 * either producer against the committed snapshots. The env fingerprint
 * records this harness as the producer (rustc = "none (C port)").
 *
 * Build & run (single core):
 *   gcc -O2 -mavx2 -o bench_kernels tools/perfport/bench_kernels.c
 *   ./bench_kernels BENCH_kernels.json BENCH_runtime.json
 */

#include <immintrin.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define LUT_SIDE 256
#define LUT_SIZE (LUT_SIDE * LUT_SIDE)
#define LUT_I16_LEN (LUT_SIZE + 1)
#define NB_I32 1024
#define NB_I16 2048

/* ------------------------------------------------------------------ */
/* kernels (ports of rust/src/compute/{lut,simd/x86,gemm}.rs)          */
/* ------------------------------------------------------------------ */

/* wrapping i32 add without C signed-overflow UB */
static inline int32_t wadd(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a + (uint32_t)b);
}

static void approx_rows_scalar(const uint8_t *x, const uint8_t *w,
                               const int32_t *lut, size_t m, size_t k,
                               size_t n, int32_t *out) {
    for (size_t mi = 0; mi < m; mi++) {
        int32_t *orow = out + mi * n;
        memset(orow, 0, n * sizeof(int32_t));
        for (size_t ki = 0; ki < k; ki++) {
            const int32_t *lrow = lut + (size_t)x[mi * k + ki] * LUT_SIDE;
            const uint8_t *wrow = w + ki * n;
            for (size_t j = 0; j < n; j++) {
                orow[j] = wadd(orow[j], lrow[wrow[j]]);
            }
        }
    }
}

static void approx_rows_avx2_i32(const uint8_t *x, const uint8_t *w,
                                 const int32_t *lut, size_t m, size_t k,
                                 size_t n, int32_t *out) {
    for (size_t mi = 0; mi < m; mi++) {
        int32_t *orow = out + mi * n;
        memset(orow, 0, n * sizeof(int32_t));
        for (size_t n0 = 0; n0 < n; n0 += NB_I32) {
            size_t nb = n - n0 < NB_I32 ? n - n0 : NB_I32;
            int32_t *oblk = orow + n0;
            for (size_t ki = 0; ki < k; ki++) {
                const int32_t *lrow = lut + (size_t)x[mi * k + ki] * LUT_SIDE;
                const uint8_t *wblk = w + ki * n + n0;
                size_t j = 0;
                for (; j + 8 <= nb; j += 8) {
                    __m128i codes =
                        _mm_loadl_epi64((const __m128i *)(wblk + j));
                    __m256i idx = _mm256_cvtepu8_epi32(codes);
                    __m256i g = _mm256_i32gather_epi32(lrow, idx, 4);
                    __m256i o = _mm256_loadu_si256((const __m256i *)(oblk + j));
                    _mm256_storeu_si256((__m256i *)(oblk + j),
                                        _mm256_add_epi32(o, g));
                }
                for (; j < nb; j++) {
                    oblk[j] = wadd(oblk[j], lrow[wblk[j]]);
                }
            }
        }
    }
}

static void approx_rows_avx2_i16(const uint8_t *x, const uint8_t *w,
                                 const int16_t *lut16, size_t m, size_t k,
                                 size_t n, int32_t *out) {
    for (size_t mi = 0; mi < m; mi++) {
        int32_t *orow = out + mi * n;
        memset(orow, 0, n * sizeof(int32_t));
        for (size_t n0 = 0; n0 < n; n0 += NB_I16) {
            size_t nb = n - n0 < NB_I16 ? n - n0 : NB_I16;
            int32_t *oblk = orow + n0;
            for (size_t ki = 0; ki < k; ki++) {
                const int16_t *lrow = lut16 + (size_t)x[mi * k + ki] * LUT_SIDE;
                const uint8_t *wblk = w + ki * n + n0;
                size_t j = 0;
                for (; j + 8 <= nb; j += 8) {
                    __m128i codes =
                        _mm_loadl_epi64((const __m128i *)(wblk + j));
                    __m256i idx = _mm256_cvtepu8_epi32(codes);
                    /* scale-2 gather over 16-bit entries; the one-entry pad
                     * keeps index 255 of the last row in bounds */
                    __m256i g =
                        _mm256_i32gather_epi32((const int *)lrow, idx, 2);
                    g = _mm256_srai_epi32(_mm256_slli_epi32(g, 16), 16);
                    __m256i o = _mm256_loadu_si256((const __m256i *)(oblk + j));
                    _mm256_storeu_si256((__m256i *)(oblk + j),
                                        _mm256_add_epi32(o, g));
                }
                for (; j < nb; j++) {
                    oblk[j] = wadd(oblk[j], (int32_t)lrow[wblk[j]]);
                }
            }
        }
    }
}

static void gemm_scalar(const float *a, const float *b, size_t m, size_t k,
                        size_t n, float *out) {
    for (size_t mi = 0; mi < m; mi++) {
        float *orow = out + mi * n;
        memset(orow, 0, n * sizeof(float));
        for (size_t ki = 0; ki < k; ki++) {
            float av = a[mi * k + ki];
            if (av == 0.0f) {
                continue;
            }
            const float *brow = b + ki * n;
            for (size_t j = 0; j < n; j++) {
                orow[j] += av * brow[j];
            }
        }
    }
}

static void gemm_avx2(const float *a, const float *b, size_t m, size_t k,
                      size_t n, float *out) {
    for (size_t mi = 0; mi < m; mi++) {
        float *orow = out + mi * n;
        memset(orow, 0, n * sizeof(float));
        for (size_t ki = 0; ki < k; ki++) {
            float av = a[mi * k + ki];
            if (av == 0.0f) {
                continue;
            }
            const float *brow = b + ki * n;
            __m256 avv = _mm256_set1_ps(av);
            size_t j = 0;
            for (; j + 8 <= n; j += 8) {
                __m256 bv = _mm256_loadu_ps(brow + j);
                __m256 ov = _mm256_loadu_ps(orow + j);
                /* mul-then-add, NOT FMA: bit-identical to the scalar loop */
                _mm256_storeu_ps(orow + j, _mm256_add_ps(ov, _mm256_mul_ps(avv, bv)));
            }
            for (; j < n; j++) {
                orow[j] += av * brow[j];
            }
        }
    }
}

/* ------------------------------------------------------------------ */
/* benchkit-compatible harness                                         */
/* ------------------------------------------------------------------ */

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + (double)ts.tv_nsec * 1e-9;
}

typedef struct {
    char name[96];
    int iters;
    double mean_s, min_s, p50_s, p90_s;
    double units; /* M-MACs (or steps) per measurement */
    const char *unit;
} Lane;

static int cmp_double(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static volatile int32_t g_sink32;
static volatile float g_sinkf;

typedef void (*work_fn)(void *);

static Lane run_lane(const char *name, double units, const char *unit,
                     work_fn f, void *arg) {
    double budget = 1.0;
    const char *bs = getenv("BENCH_BUDGET_S");
    if (bs != NULL && atof(bs) > 0.0) {
        budget = atof(bs);
    }
    double t0 = now_s();
    f(arg);
    double once = now_s() - t0;
    if (once < 1e-9) {
        once = 1e-9;
    }
    long iters = (long)(budget / once);
    if (iters < 3) {
        iters = 3;
    }
    if (iters > 10000) {
        iters = 10000;
    }
    double *samples = malloc((size_t)iters * sizeof(double));
    for (long i = 0; i < iters; i++) {
        double t = now_s();
        f(arg);
        samples[i] = now_s() - t;
    }
    qsort(samples, (size_t)iters, sizeof(double), cmp_double);
    Lane lane;
    memset(&lane, 0, sizeof(lane));
    snprintf(lane.name, sizeof(lane.name), "%s", name);
    lane.iters = (int)iters;
    double sum = 0.0;
    for (long i = 0; i < iters; i++) {
        sum += samples[i];
    }
    lane.mean_s = sum / (double)iters;
    lane.min_s = samples[0];
    lane.p50_s = samples[iters / 2];
    lane.p90_s = samples[iters * 9 / 10];
    lane.units = units;
    lane.unit = unit;
    free(samples);
    printf("%-44s p50 %10.3f ms  (min %.3f ms, n=%d)  %.1f %s/s\n", name,
           lane.p50_s * 1e3, lane.min_s * 1e3, lane.iters,
           lane.units / lane.p50_s, unit);
    return lane;
}

static void write_json(const char *path, const char *group,
                       const char *cpu_features, const char *kernel,
                       const Lane *lanes, int n_lanes) {
    FILE *fp = fopen(path, "w");
    if (fp == NULL) {
        fprintf(stderr, "cannot write %s\n", path);
        exit(1);
    }
    fprintf(fp, "{\n");
    fprintf(fp, "  \"group\": \"%s\",\n", group);
    fprintf(fp, "  \"env\": {\n");
    fprintf(fp, "    \"arch\": \"x86_64\",\n");
    fprintf(fp, "    \"cpu_features\": \"%s\",\n", cpu_features);
    fprintf(fp, "    \"kernel\": \"%s\",\n", kernel);
    fprintf(fp, "    \"os\": \"linux\",\n");
    fprintf(fp,
            "    \"rustc\": \"none (tools/perfport C port; no Rust toolchain "
            "in the build container)\",\n");
    fprintf(fp, "    \"threads\": 1\n");
    fprintf(fp, "  },\n");
    fprintf(fp, "  \"results\": [\n");
    for (int i = 0; i < n_lanes; i++) {
        const Lane *l = &lanes[i];
        fprintf(fp,
                "    {\n      \"name\": \"%s\",\n      \"iters\": %d,\n"
                "      \"mean_s\": %.9g,\n      \"min_s\": %.9g,\n"
                "      \"p50_s\": %.9g,\n      \"p90_s\": %.9g,\n"
                "      \"units\": %.9g,\n      \"unit\": \"%s\",\n"
                "      \"per_s\": %.9g\n    }%s\n",
                l->name, l->iters, l->mean_s, l->min_s, l->p50_s, l->p90_s,
                l->units, l->unit, l->units / l->p50_s,
                i + 1 < n_lanes ? "," : "");
    }
    fprintf(fp, "  ]\n}\n");
    fclose(fp);
    printf("wrote %s\n", path);
}

/* ------------------------------------------------------------------ */
/* workloads                                                           */
/* ------------------------------------------------------------------ */

#define M 4096
#define K 144
#define N 32

typedef struct {
    uint8_t *x;
    uint8_t *w;
    int32_t *lut;
    int16_t *lut16;
    int32_t *out;
    float *fa, *fb, *fg, *fout;
} Work;

static void lane_lut_scalar(void *p) {
    Work *wk = p;
    approx_rows_scalar(wk->x, wk->w, wk->lut, M, K, N, wk->out);
    g_sink32 = wk->out[M * N - 1];
}

static void lane_lut_avx2(void *p) {
    Work *wk = p;
    approx_rows_avx2_i32(wk->x, wk->w, wk->lut, M, K, N, wk->out);
    g_sink32 = wk->out[M * N - 1];
}

static void lane_lut_avx2_i16(void *p) {
    Work *wk = p;
    approx_rows_avx2_i16(wk->x, wk->w, wk->lut16, M, K, N, wk->out);
    g_sink32 = wk->out[M * N - 1];
}

static void lane_gemm_scalar(void *p) {
    Work *wk = p;
    gemm_scalar(wk->fa, wk->fb, M, K, N, wk->fout);
    g_sinkf = wk->fout[M * N - 1];
}

static void lane_gemm_avx2(void *p) {
    Work *wk = p;
    gemm_avx2(wk->fa, wk->fb, M, K, N, wk->fout);
    g_sinkf = wk->fout[M * N - 1];
}

/* one "train-step-like" composite: forward LUT matmul + two trainer GEMMs
 * (the per-step hot loops of the native train_qat path) */
static void lane_step_scalar(void *p) {
    lane_lut_scalar(p);
    lane_gemm_scalar(p);
    lane_gemm_scalar(p);
}

static void lane_step_avx2(void *p) {
    lane_lut_avx2_i16(p);
    lane_gemm_avx2(p);
    lane_gemm_avx2(p);
}

static uint32_t lcg(uint32_t *s) {
    *s = *s * 1664525u + 1013904223u;
    return *s >> 8;
}

int main(int argc, char **argv) {
    const char *kpath = argc > 1 ? argv[1] : "BENCH_kernels.json";
    const char *rpath = argc > 2 ? argv[2] : "BENCH_runtime.json";

    if (!__builtin_cpu_supports("avx2")) {
        fprintf(stderr, "host has no AVX2; the simd lanes would be dishonest — aborting\n");
        return 1;
    }
    const char *features =
        __builtin_cpu_supports("fma") ? "avx2,fma" : "avx2";

    Work wk;
    wk.x = malloc(M * K);
    wk.w = malloc(K * N);
    wk.lut = malloc(LUT_SIZE * sizeof(int32_t));
    wk.lut16 = malloc(LUT_I16_LEN * sizeof(int16_t));
    wk.out = malloc(M * N * sizeof(int32_t));
    wk.fa = malloc(M * K * sizeof(float));
    wk.fb = malloc(K * N * sizeof(float));
    wk.fg = malloc(M * N * sizeof(float));
    wk.fout = malloc(M * N * sizeof(float));
    uint32_t seed = 1u;
    for (size_t i = 0; i < M * K; i++) {
        wk.x[i] = (uint8_t)lcg(&seed);
        wk.fa[i] = (float)(lcg(&seed) % 2048) / 1024.0f - 1.0f;
    }
    for (size_t i = 0; i < K * N; i++) {
        wk.w[i] = (uint8_t)lcg(&seed);
        wk.fb[i] = (float)(lcg(&seed) % 2048) / 1024.0f - 1.0f;
    }
    for (size_t i = 0; i < M * N; i++) {
        wk.fg[i] = (float)(lcg(&seed) % 2048) / 1024.0f - 1.0f;
    }
    /* signed-activation exact product table (the same shape the lowering
     * pass packs to i16: every cell in [-32640, 32385]) */
    for (int r = 0; r < LUT_SIDE; r++) {
        for (int c = 0; c < LUT_SIDE; c++) {
            wk.lut[r * LUT_SIDE + c] = (r - 128) * (c - 128);
        }
    }
    for (int i = 0; i < LUT_SIZE; i++) {
        wk.lut16[i] = (int16_t)wk.lut[i];
    }
    wk.lut16[LUT_SIZE] = 0; /* gather pad */

    /* cross-check: all three LUT kernels must agree bit-for-bit before any
     * timing is recorded */
    int32_t *ref = malloc(M * N * sizeof(int32_t));
    approx_rows_scalar(wk.x, wk.w, wk.lut, M, K, N, ref);
    approx_rows_avx2_i32(wk.x, wk.w, wk.lut, M, K, N, wk.out);
    if (memcmp(ref, wk.out, M * N * sizeof(int32_t)) != 0) {
        fprintf(stderr, "avx2 i32 kernel diverged from scalar\n");
        return 1;
    }
    approx_rows_avx2_i16(wk.x, wk.w, wk.lut16, M, K, N, wk.out);
    if (memcmp(ref, wk.out, M * N * sizeof(int32_t)) != 0) {
        fprintf(stderr, "avx2 i16 kernel diverged from scalar\n");
        return 1;
    }
    float *fref = malloc(M * N * sizeof(float));
    gemm_scalar(wk.fa, wk.fb, M, K, N, fref);
    gemm_avx2(wk.fa, wk.fb, M, K, N, wk.fout);
    if (memcmp(fref, wk.fout, M * N * sizeof(float)) != 0) {
        fprintf(stderr, "avx2 gemm diverged from scalar (FMA leak?)\n");
        return 1;
    }
    free(ref);
    free(fref);
    printf("kernel cross-check passed: avx2 i32/i16 + gemm bit-identical to scalar\n");

    double macs = (double)M * K * N / 1e6;
    Lane kernels[5];
    kernels[0] = run_lane("approx_matmul_pool/scalar/t1/4096x144x32", macs,
                          "M-MACs", lane_lut_scalar, &wk);
    kernels[1] = run_lane("approx_matmul_pool/simd/t1/4096x144x32", macs,
                          "M-MACs", lane_lut_avx2, &wk);
    kernels[2] = run_lane("approx_matmul_pool/simd_i16/t1/4096x144x32", macs,
                          "M-MACs", lane_lut_avx2_i16, &wk);
    kernels[3] = run_lane("gemm/scalar/t1/4096x144x32", macs, "M-MACs",
                          lane_gemm_scalar, &wk);
    kernels[4] = run_lane("gemm/simd/t1/4096x144x32", macs, "M-MACs",
                          lane_gemm_avx2, &wk);
    write_json(kpath, "simulator", features, "avx2", kernels, 5);

    Lane runtime[2];
    runtime[0] = run_lane("cport/scalar/t1/train_step_proxy", 1.0, "steps",
                          lane_step_scalar, &wk);
    runtime[1] = run_lane("cport/simd/t1/train_step_proxy", 1.0, "steps",
                          lane_step_avx2, &wk);
    write_json(rpath, "runtime", features, "avx2", runtime, 2);

    if (kernels[1].p50_s >= kernels[0].p50_s) {
        fprintf(stderr,
                "WARNING: simd lane did not beat scalar on p50 "
                "(%.3f ms vs %.3f ms)\n",
                kernels[1].p50_s * 1e3, kernels[0].p50_s * 1e3);
        return 2;
    }
    return 0;
}
