#!/usr/bin/env python3
"""Regenerate the committed golden IR files under rust/tests/golden_ir/.

Bit-exact offline port of what `UPDATE_GOLDENS=1 cargo test golden_ir`
writes: for every synthetic-zoo model, the digest-stripped model IR
(`export_ir(model).with_params_digest().to_json_string()` in Rust). Useful
on machines without a Rust toolchain; on machines with one, the cargo
route is equally valid and must produce byte-identical files.

The port reproduces, bit for bit:

- PCG32 (XSH-RR) including the two-step seeding sequence
  (`util/rng.rs::Pcg32::new`) — self-checked below against the published
  reference vector for seed 42 / stream 54 before anything is written;
- `normal_det` (Irwin-Hall: sum of 12 exact f64 uniforms minus 6);
- the He-normal f32 init chain (`f32 std * f32(normal_det)`, numpy
  single-precision IEEE ops match Rust's);
- the synthetic zoo builders (`runtime/synthetic.rs`), FNV-1a 64 digests
  (`ir/model.rs::params_digest`) and the deterministic JSON writer
  (`util/json.rs`: sorted keys, 2-space indent — `json.dumps` with
  `sort_keys=True, indent=2` emits the identical bytes for the all-integer
  golden payload).

Run from anywhere: `python3 tools/gen_goldens.py`.
"""

import json
import os
import struct

import numpy as np

MASK64 = (1 << 64) - 1
PCG_MULT = 6364136223846793005
LUT_SIZE = 65536
BATCH = 16

OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "rust", "tests", "golden_ir")


# ---------------------------------------------------------------------------
# PCG32 (util/rng.rs)

class Pcg32:
    def __init__(self, seed: int, stream: int):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + seed) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        x = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((x >> rot) | (x << ((32 - rot) & 31))) & 0xFFFFFFFF

    def normal_det_block(self, n: int) -> list:
        """n draws of normal_det(): sum of 12 exact f64 uniforms - 6.0."""
        out = []
        state = self.state
        inc = self.inc
        scale = 2.0 ** -53
        for _ in range(n):
            s = 0.0
            for _ in range(12):
                old = state
                state = (old * PCG_MULT + inc) & MASK64
                x = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
                rot = old >> 59
                hi = ((x >> rot) | (x << ((32 - rot) & 31))) & 0xFFFFFFFF
                old = state
                state = (old * PCG_MULT + inc) & MASK64
                x = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
                rot = old >> 59
                lo = ((x >> rot) | (x << ((32 - rot) & 31))) & 0xFFFFFFFF
                s += (((hi << 32) | lo) >> 11) * scale
            out.append(s - 6.0)
        self.state = state
        return out


def self_check_pcg32():
    """Published XSH-RR reference vector (O'Neill's pcg32-demo, seed 42,
    stream 54). A mismatch means the port is wrong — abort, write nothing."""
    rng = Pcg32(42, 54)
    got = [rng.next_u32() for _ in range(6)]
    want = [0xA15C02B7, 0x7B47F409, 0xBA1D3330, 0x83D2F293, 0xBFA4784B, 0xCBED606E]
    assert got == want, f"PCG32 port broken: {[hex(v) for v in got]}"


# ---------------------------------------------------------------------------
# digests (ir/model.rs)

def fnv64_bytes(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK64
    return h


def params_digest(values: np.ndarray) -> str:
    return format(fnv64_bytes(values.astype("<f4").tobytes()), "016x")


# ---------------------------------------------------------------------------
# synthetic zoo builder (runtime/synthetic.rs)

class Builder:
    def __init__(self, model: str):
        self.layers = []
        self.leaves = []
        self.init = []  # list of np.float32 arrays, concatenated at the end
        self.count = 0
        self.rng = Pcg32(fnv64_bytes(model.encode()), 0x5E11717)

    def leaf(self, path, shape, values: np.ndarray):
        assert int(np.prod(shape)) == values.size
        self.leaves.append({"path": path, "offset": self.count, "shape": list(shape)})
        self.init.append(values.astype(np.float32, copy=False))
        self.count += values.size

    def he_normal(self, n: int, fan_in: int) -> np.ndarray:
        # f32 std times f32-cast normal_det draws, multiplied in f32 —
        # the exact operation order of Builder::he_normal in Rust
        std = np.sqrt(np.float32(2.0) / np.float32(fan_in))
        draws = np.array(self.rng.normal_det_block(n), dtype=np.float64)
        return (std * draws.astype(np.float32)).astype(np.float32)

    def conv(self, name, cin, cout, k, stride, pad, in_hw, act_signed):
        out_hw = ((in_hw[0] + 2 * pad - k) // stride + 1, (in_hw[1] + 2 * pad - k) // stride + 1)
        fan_in = k * k * cin
        self.layers.append({
            "name": name, "kind": "conv", "cin": cin, "cout": cout, "k": k,
            "stride": stride, "pad": pad, "in_hw": list(in_hw), "out_hw": list(out_hw),
            "fan_in": fan_in, "mults_per_image": out_hw[0] * out_hw[1] * fan_in * cout,
            "act_signed": act_signed,
        })
        self.leaf(f"{name}/w", [k, k, cin, cout], self.he_normal(fan_in * cout, fan_in))
        self.leaf(f"{name}/gamma", [cout], np.ones(cout, dtype=np.float32))
        self.leaf(f"{name}/beta", [cout], np.zeros(cout, dtype=np.float32))
        return out_hw

    def fc(self, name, cin, cout, act_signed):
        self.layers.append({
            "name": name, "kind": "fc", "cin": cin, "cout": cout, "k": 1,
            "stride": 1, "pad": 0, "in_hw": [1, 1], "out_hw": [1, 1],
            "fan_in": cin, "mults_per_image": cin * cout, "act_signed": act_signed,
        })
        self.leaf(f"{name}/w", [cin, cout], self.he_normal(cin * cout, cin))
        self.leaf(f"{name}/b", [cout], np.zeros(cout, dtype=np.float32))

    def tinynet(self, hw, classes, act_signed):
        h1 = self.conv("conv0", 3, 8, 3, 1, 1, hw, act_signed)
        self.conv("conv1", 8, 16, 3, 2, 1, h1, act_signed)
        self.fc("fc", 16, classes, act_signed)

    def resnet(self, n, hw, classes, act_signed):
        widths = [8, 16, 32]
        cur_hw = self.conv("conv0", 3, widths[0], 3, 1, 1, hw, act_signed)
        cin = widths[0]
        for s, cout in enumerate(widths):
            for blk in range(n):
                stride = 2 if s > 0 and blk == 0 else 1
                base = f"s{s}b{blk}"
                mid_hw = self.conv(f"{base}_conv1", cin, cout, 3, stride, 1, cur_hw, act_signed)
                self.conv(f"{base}_conv2", cout, cout, 3, 1, 1, mid_hw, act_signed)
                if stride != 1 or cin != cout:
                    self.conv(f"{base}_short", cin, cout, 1, stride, 0, cur_hw, act_signed)
                cur_hw = mid_hw
                cin = cout
        self.fc("fc", widths[2], classes, act_signed)

    def vgg(self, hw, classes, act_signed):
        plan = [(3, 8), (8, 8), (8, 16), (16, 16), (16, 32), (32, 32)]
        cur_hw = hw
        for i, (cin, cout) in enumerate(plan):
            cur_hw = self.conv(f"conv{i}", cin, cout, 3, 1, 1, cur_hw, act_signed)
            if i % 2 == 1 and i + 1 < len(plan):
                cur_hw = (cur_hw[0] // 2, cur_hw[1] // 2)
        self.fc("fc", 32, classes, act_signed)


MODELS = {
    # model -> (family, arch, hw, classes, act_signed)
    "tinynet": ("tiny", "tinynet", (8, 8), 10, False),
    "resnet8": (("resnet", 1), "resnet8", (8, 8), 10, False),
    "resnet14": (("resnet", 2), "resnet14", (8, 8), 10, False),
    "resnet20": (("resnet", 3), "resnet20", (8, 8), 10, False),
    "resnet32": (("resnet", 5), "resnet32", (8, 8), 10, False),
    "vgg16": ("vgg", "vgg16", (16, 16), 20, False),
    "vgg16_signed": ("vgg", "vgg16", (16, 16), 20, True),
}

MODEL_ORDER = ["tinynet", "resnet8", "resnet14", "resnet20", "resnet32", "vgg16", "vgg16_signed"]


# ---------------------------------------------------------------------------
# program signatures (runtime/synthetic.rs::program_signatures)

def program_signatures(n, l, hw, channels, batch):
    f32 = lambda shape: {"dtype": "float32", "shape": shape}
    i32 = lambda shape: {"dtype": "int32", "shape": shape}
    u32 = lambda shape: {"dtype": "uint32", "shape": shape}
    x = f32([batch, hw[0], hw[1], channels])
    y = i32([batch])
    scalar = lambda: f32([])
    params = lambda: f32([n])
    per_layer = lambda: f32([l])
    luts = lambda: i32([l, LUT_SIZE])
    seed = lambda: u32([2])
    metrics3 = lambda: f32([3])
    metrics5 = lambda: f32([5])

    def prog(name, inputs, outputs):
        return {"file": f"<native:{name}>", "inputs": inputs, "outputs": outputs}

    return {
        "eval": prog("eval", [params(), x, y], [metrics3()]),
        "eval_agn": prog("eval_agn", [params(), per_layer(), x, y, seed()], [metrics3()]),
        "eval_approx": prog("eval_approx", [params(), x, y, luts(), per_layer()], [metrics3()]),
        "train_qat": prog(
            "train_qat",
            [params(), params(), x, y, scalar()],
            [params(), params(), metrics3()],
        ),
        "train_agn": prog(
            "train_agn",
            [params(), params(), per_layer(), per_layer(), x, y, seed(), scalar(), scalar(), scalar()],
            [params(), params(), per_layer(), per_layer(), metrics5()],
        ),
        "train_approx": prog(
            "train_approx",
            [params(), params(), x, y, scalar(), luts(), per_layer()],
            [params(), params(), metrics3()],
        ),
        "calibrate": prog("calibrate", [params(), x, y], [per_layer(), per_layer(), metrics3()]),
    }


# ---------------------------------------------------------------------------
# IR assembly (ir/model.rs::from_manifest + with_params_digest)

QUANT_FLOAT32 = {"bitwidth": 32, "scale": None, "scheme": "float32"}
QUANT_INT8 = {"bitwidth": 8, "scale": None, "scheme": "int8_symmetric"}
QUANT_UINT8 = {"bitwidth": 8, "scale": None, "scheme": "uint8_affine"}


def model_ir(model: str) -> dict:
    family, arch, hw, classes, act_signed = MODELS[model]
    b = Builder(model)
    if family == "tiny":
        b.tinynet(hw, classes, act_signed)
    elif family == "vgg":
        b.vgg(hw, classes, act_signed)
    else:
        b.resnet(family[1], hw, classes, act_signed)

    flat = np.concatenate(b.init) if b.init else np.zeros(0, dtype=np.float32)
    assert flat.size == b.count
    tensors = [
        {
            "offset": leaf["offset"],
            "path": leaf["path"],
            "quant": dict(QUANT_INT8 if leaf["path"].endswith("/w") else QUANT_FLOAT32),
            "shape": leaf["shape"],
        }
        for leaf in b.leaves
    ]
    layers = [
        {
            "act_quant": dict(QUANT_INT8 if l["act_signed"] else QUANT_UINT8),
            "act_signed": l["act_signed"],
            "cin": l["cin"],
            "cout": l["cout"],
            "fan_in": l["fan_in"],
            "in_hw": l["in_hw"],
            "k": l["k"],
            "kind": l["kind"],
            "mults_per_image": l["mults_per_image"],
            "name": l["name"],
            "out_hw": l["out_hw"],
            "pad": l["pad"],
            "stride": l["stride"],
        }
        for l in b.layers
    ]
    return {
        "act_signed": act_signed,
        "arch": arch,
        "batch": BATCH,
        "classes": classes,
        "hints": {
            "batch": BATCH,
            "lut_bytes_per_layer": LUT_SIZE * 4,
            "param_bytes": b.count * 4,
            "preferred_threads": 0,
            "total_mults_per_image": sum(l["mults_per_image"] for l in b.layers),
        },
        "init_params_file": f"<synthetic:{model}>",
        "input_shape": [hw[0], hw[1], 3],
        "layers": layers,
        "model": model,
        "num_layers": len(b.layers),
        "param_count": b.count,
        "params": {"count": b.count, "encoding": "digest", "fnv64": params_digest(flat)},
        "programs": program_signatures(b.count, len(b.layers), hw, 3, BATCH),
        "schema_version": 1,
    }


def main():
    self_check_pcg32()
    # f64 -> f32 cast sanity: numpy must round-to-nearest-even like Rust `as`
    assert np.float32(1.0 + 2.0**-24).item() == 1.0  # exact midpoint -> even
    assert np.float32(1.0 + 2.0**-23).item() > 1.0
    assert struct.pack("<f", np.float32(1.0)) == b"\x00\x00\x80\x3f"
    os.makedirs(OUT_DIR, exist_ok=True)
    for model in MODEL_ORDER:
        ir = model_ir(model)
        text = json.dumps(ir, indent=2, sort_keys=True) + "\n"
        path = os.path.join(OUT_DIR, f"{model}.ir.json")
        with open(path, "w") as f:
            f.write(text)
        print(f"{model}: {ir['num_layers']} layers, {ir['param_count']} params, "
              f"fnv64 {ir['params']['fnv64']} -> {os.path.relpath(path)}")


if __name__ == "__main__":
    main()
