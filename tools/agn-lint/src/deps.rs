//! AGN-D7 — dependency policy. The offline crate set (DESIGN.md) pins the
//! default build's external dependencies to exactly `anyhow` + `log`;
//! anything else must be `optional = true` (feature-gated, like the
//! `vendor/xla` API stub) or live in a sanctioned verification-only target
//! table (`cfg(loom)` / `cfg(miri)`). This is a purpose-built scan of the
//! manifest's dependency tables, not a general TOML parser: it understands
//! exactly the constructs Cargo.toml uses for dependencies (section
//! headers, `name = value` lines, inline tables, `[dependencies.name]`
//! subsections) and nothing more.

use crate::diag::Diag;

const ALLOWED_DEFAULT: &[&str] = &["anyhow", "log"];

/// What a `[section]` header means for the dependency policy.
enum Section {
    /// Counts against the default dependency set.
    Active,
    /// dev-dependencies / sanctioned cfg tables / non-dependency tables.
    Ignored,
    /// `[dependencies.NAME]` header form: the dep named in the header.
    ActiveHeader(String),
}

fn classify(section: &str) -> Section {
    let s = section.trim();
    if s.contains("dev-dependencies") {
        return Section::Ignored;
    }
    if let Some(rest) = s.strip_prefix("target.") {
        // [target.'cfg(...)'.dependencies] — active in default builds for
        // matching targets, so it counts, unless the cfg is a sanctioned
        // verification-only lane (loom / miri) that default builds never
        // enable.
        if !rest.contains(".dependencies") {
            return Section::Ignored;
        }
        if rest.contains("loom") || rest.contains("miri") {
            return Section::Ignored;
        }
        return Section::Active;
    }
    if s == "dependencies" || s == "build-dependencies" || s == "workspace.dependencies" {
        return Section::Active;
    }
    for prefix in ["dependencies.", "build-dependencies.", "workspace.dependencies."] {
        if let Some(name) = s.strip_prefix(prefix) {
            return Section::ActiveHeader(name.trim().to_string());
        }
    }
    Section::Ignored
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut prev_backslash = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_backslash => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    line
}

fn is_optional_inline(value: &str) -> bool {
    // `{ path = "...", optional = true }`
    value.split(',').any(|part| {
        let mut kv = part.splitn(2, '=');
        let k = kv.next().unwrap_or("").trim().trim_start_matches('{');
        let v = kv.next().unwrap_or("").trim().trim_end_matches('}');
        k.trim() == "optional" && v.trim() == "true"
    })
}

pub fn check_manifest(display_path: &str, src: &str) -> Vec<Diag> {
    let mut diags = Vec::new();
    let mut section = Section::Ignored;
    // deferred [dependencies.NAME] judgement: (name, header line, optional?)
    let mut pending: Option<(String, u32, bool)> = None;

    let mut finalize = |pending: &mut Option<(String, u32, bool)>, diags: &mut Vec<Diag>| {
        if let Some((name, line, optional)) = pending.take() {
            if !optional && !ALLOWED_DEFAULT.contains(&name.as_str()) {
                diags.push(Diag {
                    file: display_path.to_string(),
                    line,
                    rule: "AGN-D7",
                    message: format!(
                        "non-optional dependency `{name}` grows the default set beyond \
                         anyhow+log; gate it behind a feature (optional = true) or drop it"
                    ),
                });
            }
        }
    };

    for (idx, raw) in src.lines().enumerate() {
        let line_no = (idx + 1) as u32;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            finalize(&mut pending, &mut diags);
            let name = line[1..line.len() - 1].replace(['\'', '"'], "");
            section = classify(&name);
            if let Section::ActiveHeader(dep) = &section {
                pending = Some((dep.clone(), line_no, false));
            }
            continue;
        }
        match &section {
            Section::Ignored => {}
            Section::ActiveHeader(_) => {
                let mut kv = line.splitn(2, '=');
                let k = kv.next().unwrap_or("").trim();
                let v = kv.next().unwrap_or("").trim();
                if k == "optional" && v == "true" {
                    if let Some(p) = pending.as_mut() {
                        p.2 = true;
                    }
                }
            }
            Section::Active => {
                let mut kv = line.splitn(2, '=');
                let name = kv.next().unwrap_or("").trim().replace(['\'', '"'], "");
                let value = kv.next().unwrap_or("").trim();
                if name.is_empty() || value.is_empty() {
                    continue;
                }
                if ALLOWED_DEFAULT.contains(&name.as_str()) || is_optional_inline(value) {
                    continue;
                }
                diags.push(Diag {
                    file: display_path.to_string(),
                    line: line_no,
                    rule: "AGN-D7",
                    message: format!(
                        "non-optional dependency `{name}` grows the default set beyond \
                         anyhow+log; gate it behind a feature (optional = true) or drop it"
                    ),
                });
            }
        }
    }
    finalize(&mut pending, &mut diags);
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allows_the_pinned_set_and_optional_deps() {
        let toml = r#"
[package]
name = "x"
[dependencies]
anyhow = "1"
log = "0.4"
xla = { path = "vendor/xla", optional = true }
[target.'cfg(loom)'.dependencies]
loom = { path = "vendor/loom" }
[dev-dependencies]
criterion = "0.5"
"#;
        assert!(check_manifest("Cargo.toml", toml).is_empty());
    }

    #[test]
    fn flags_new_default_deps() {
        let toml = "[dependencies]\nanyhow = \"1\"\nrand = \"0.8\"\n";
        let ds = check_manifest("Cargo.toml", toml);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].rule, "AGN-D7");
        assert_eq!(ds[0].line, 3);
        assert!(ds[0].message.contains("rand"));
    }

    #[test]
    fn header_form_and_target_tables() {
        let toml = "[dependencies.serde]\nversion = \"1\"\n";
        assert_eq!(check_manifest("Cargo.toml", toml).len(), 1);
        let optional = "[dependencies.serde]\nversion = \"1\"\noptional = true\n";
        assert!(check_manifest("Cargo.toml", optional).is_empty());
        let target = "[target.'cfg(unix)'.dependencies]\nlibc = \"0.2\"\n";
        assert_eq!(check_manifest("Cargo.toml", target).len(), 1);
    }
}
