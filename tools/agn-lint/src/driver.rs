//! File discovery and orchestration: walk the scan roots, run the source
//! rules over every `.rs` file, run the dependency policy over the
//! manifest(s), and return the sorted diagnostic list.

use std::path::{Path, PathBuf};

use crate::deps;
use crate::diag::Diag;
use crate::policy::{module_rel, Policy};
use crate::rules;

/// Directory names that never hold contract-bound lib code.
const SKIP_DIRS: &[&str] = &["target", "vendor", "fixtures", "tests", "benches", "examples"];

pub struct Report {
    pub diags: Vec<Diag>,
    pub files_checked: usize,
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read directory {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("read_dir entry under {}: {e}", dir.display()))?;
        entries.push(entry.path());
    }
    // read_dir order is filesystem-dependent; sort so diagnostics and
    // files_checked are reproducible everywhere.
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if SKIP_DIRS.contains(&name) || name.starts_with('.') {
                continue;
            }
            walk(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Normalize a path for display: `/` separators, no leading `./`.
fn display_path(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Scan `roots` (files or directories) with `policy`, plus `manifests`
/// under the AGN-D7 dependency policy.
pub fn run(roots: &[PathBuf], manifests: &[PathBuf], policy: &Policy) -> Result<Report, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        if root.is_dir() {
            walk(root, &mut files)?;
        } else if root.is_file() {
            files.push(root.clone());
        } else {
            return Err(format!("no such file or directory: {}", root.display()));
        }
    }
    files.sort();
    files.dedup();

    let mut diags: Vec<Diag> = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| format!("cannot read {}: {e}", f.display()))?;
        let disp = display_path(f);
        let rel = module_rel(&disp);
        diags.extend(rules::check_source(&disp, &rel, &src, policy));
    }
    let mut files_checked = files.len();
    for m in manifests {
        let src = std::fs::read_to_string(m)
            .map_err(|e| format!("cannot read {}: {e}", m.display()))?;
        diags.extend(deps::check_manifest(&display_path(m), &src));
        files_checked += 1;
    }
    diags.sort();
    Ok(Report { diags, files_checked })
}

/// Discover the manifest governing a scan root: `<root>/Cargo.toml`, else
/// `<root>/../Cargo.toml` (covers the conventional `rust/src` root whose
/// package manifest sits one level up).
pub fn discover_manifest(root: &Path) -> Option<PathBuf> {
    if !root.is_dir() {
        return None;
    }
    let own = root.join("Cargo.toml");
    if own.is_file() {
        return Some(own);
    }
    let parent = root.parent()?.join("Cargo.toml");
    if parent.is_file() {
        return Some(parent);
    }
    None
}
