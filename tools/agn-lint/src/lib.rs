//! agn-lint — the machine-checked half of the repo's determinism contract
//! (README §Determinism contract).
//!
//! The simulation stack promises bit-identical results at any thread count,
//! across resume, and across machines. Most ways to silently break that
//! promise are lexically visible: iterating a `RandomState`-seeded map,
//! an unpinned float reduction, an ambient `env`/clock read, an `unsafe`
//! block nobody justified, wraparound arithmetic outside the modeled
//! domain. This crate walks `rust/src/**` and turns each of those contracts
//! into a rule with a stable ID:
//!
//! | ID     | rule                                                      |
//! |--------|-----------------------------------------------------------|
//! | AGN-D1 | no `HashMap`/`HashSet` iteration in lib code              |
//! | AGN-D2 | `wrapping_*` only in the modeled-wraparound domain        |
//! | AGN-D3 | `unsafe` allowlisted + `// SAFETY:` justified             |
//! | AGN-D4 | no ambient nondeterminism (env/clock/entropy) reads       |
//! | AGN-D5 | float `.sum()`/`.fold()` reductions confined to compute:: |
//! | AGN-D6 | `#[allow(...)]` needs an invariant comment                |
//! | AGN-D7 | default dependency set stays `anyhow` + `log`             |
//!
//! Diagnostics carry `file:line`, render as human lines or a deterministic
//! JSON report, and can be waived in place with
//! `// lint:allow(AGN-Dn) <reason>`. The binary (`cargo run -p agn-lint --
//! --deny rust/src`) exits non-zero on violations under `--deny`; the
//! fixture corpus under `tests/fixtures/` pins each rule's behavior.

pub mod deps;
pub mod diag;
pub mod driver;
pub mod lexer;
pub mod policy;
pub mod rules;
