//! Minimal lossless Rust token scanner.
//!
//! The determinism-contract rules (see [`crate::rules`]) need three things a
//! regex grep cannot provide: (1) code tokens reliably separated from string
//! literals and comments, (2) the comment map itself (for `// SAFETY:` and
//! justification checks), and (3) per-token line numbers for `file:line`
//! diagnostics. A full parser adds nothing the rules use, so this module
//! implements just the lexical grammar: line comments, nested block
//! comments, string / raw-string / byte-string / char literals, raw
//! identifiers, lifetimes (disambiguated from char literals), and numeric
//! literals with float detection (`0.0`, `1e-3`, `7f32` are floats; `0..n`
//! stays two `.` puncts and `1.max(2)` stays an integer method call).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Str,
    Char,
    Lifetime,
    Int,
    Float,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }
}

/// A comment span. `start_line..=end_line` covers every source line the
/// comment touches (block comments may span several).
#[derive(Clone, Debug)]
pub struct Comment {
    pub start_line: u32,
    pub end_line: u32,
    pub text: String,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

impl Lexed {
    /// Comments that touch `line` (inclusive span check).
    pub fn comments_on(&self, line: u32) -> impl Iterator<Item = &Comment> {
        self.comments.iter().filter(move |c| c.start_line <= line && line <= c.end_line)
    }

    /// True if any comment touching `lo..=hi` contains `needle`.
    pub fn comment_in_range_contains(&self, lo: u32, hi: u32, needle: &str) -> bool {
        self.comments
            .iter()
            .any(|c| c.start_line <= hi && c.end_line >= lo && c.text.contains(needle))
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Scanner {
    cs: Vec<char>,
    i: usize,
    line: u32,
    out: Lexed,
}

impl Scanner {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    fn text(&self, lo: usize, hi: usize) -> String {
        self.cs[lo.min(self.cs.len())..hi.min(self.cs.len())].iter().collect()
    }

    fn push(&mut self, kind: Kind, text: String, line: u32) {
        self.out.toks.push(Tok { kind, text, line });
    }

    /// Consume a `"..."` body with `self.i` on the opening quote.
    fn scan_plain_string(&mut self) {
        let sl = self.line;
        let start = self.i + 1;
        self.i += 1;
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c == '\\' && self.i + 1 < self.cs.len() {
                if self.cs[self.i + 1] == '\n' {
                    self.line += 1;
                }
                self.i += 2;
                continue;
            }
            if c == '"' {
                break;
            }
            if c == '\n' {
                self.line += 1;
            }
            self.i += 1;
        }
        let text = self.text(start, self.i);
        self.i += 1; // closing quote (or EOF)
        self.push(Kind::Str, text, sl);
    }

    /// Consume `r"…"` / `r#"…"#` with `self.i` on the opening quote and
    /// `hashes` guard characters expected after the closing quote.
    fn scan_raw_string(&mut self, hashes: usize) {
        let sl = self.line;
        let start = self.i + 1;
        self.i += 1;
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c == '\n' {
                self.line += 1;
                self.i += 1;
                continue;
            }
            if c == '"' {
                let mut k = 0;
                while k < hashes && self.peek(1 + k) == Some('#') {
                    k += 1;
                }
                if k == hashes {
                    let text = self.text(start, self.i);
                    self.i += 1 + hashes;
                    self.push(Kind::Str, text, sl);
                    return;
                }
            }
            self.i += 1;
        }
        // unterminated: emit what we have
        let text = self.text(start, self.cs.len());
        self.push(Kind::Str, text, sl);
    }

    /// Try the `r`/`b`-prefixed literal forms (`r"…"`, `r#"…"#`, `b"…"`,
    /// `br"…"`, `b'…'`, `r#ident`). Returns true if one was consumed.
    fn try_prefixed(&mut self) -> bool {
        let mut j = 0usize;
        if self.peek(j) == Some('b') {
            j += 1;
        }
        let saw_r = self.peek(j) == Some('r');
        if saw_r {
            j += 1;
        }
        let mut hashes = 0usize;
        if saw_r {
            while self.peek(j) == Some('#') {
                hashes += 1;
                j += 1;
            }
        }
        match self.peek(j) {
            Some('"') if saw_r => {
                self.i += j;
                self.scan_raw_string(hashes);
                true
            }
            Some('"') if j == 1 => {
                // b"…": plain-string body rules
                self.i += j;
                self.scan_plain_string();
                true
            }
            Some('\'') if j == 1 && !saw_r => {
                // b'…': byte literal; reuse char-literal scanning
                self.i += j;
                self.scan_char_or_lifetime();
                true
            }
            Some(c) if saw_r && hashes == 1 && is_ident_start(c) => {
                // raw identifier r#ident — strip the r# prefix
                let start = self.i + j;
                let mut k = start;
                while k < self.cs.len() && is_ident_continue(self.cs[k]) {
                    k += 1;
                }
                let text = self.text(start, k);
                let line = self.line;
                self.i = k;
                self.push(Kind::Ident, text, line);
                true
            }
            _ => false,
        }
    }

    /// `self.i` is on a `'`: char literal or lifetime.
    fn scan_char_or_lifetime(&mut self) {
        let line = self.line;
        match self.peek(1) {
            Some('\\') => {
                // escaped char: '\n', '\'', '\\', '\u{…}'
                let mut j = self.i + 2;
                if j < self.cs.len() {
                    j += 1; // escape body (covers \' and \\)
                }
                while j < self.cs.len() && self.cs[j] != '\'' {
                    j += 1; // \u{…} tail
                }
                self.push(Kind::Char, String::new(), line);
                self.i = (j + 1).min(self.cs.len());
            }
            Some(c) if is_ident_start(c) => {
                let mut j = self.i + 2;
                while j < self.cs.len() && is_ident_continue(self.cs[j]) {
                    j += 1;
                }
                if j == self.i + 2 && self.peek(2) == Some('\'') {
                    // 'x'
                    let text = self.text(self.i + 1, j);
                    self.push(Kind::Char, text, line);
                    self.i = j + 1;
                } else {
                    // 'lifetime (including '_)
                    let text = self.text(self.i + 1, j);
                    self.push(Kind::Lifetime, text, line);
                    self.i = j;
                }
            }
            Some(_) if self.peek(2) == Some('\'') => {
                // non-ident char like '+' or ' '
                let text = self.text(self.i + 1, self.i + 2);
                self.push(Kind::Char, text, line);
                self.i += 3;
            }
            _ => {
                self.push(Kind::Punct, "'".to_string(), line);
                self.i += 1;
            }
        }
    }

    /// `self.i` is on an ASCII digit.
    fn scan_number(&mut self) {
        let start = self.i;
        let line = self.line;
        let mut is_float = false;
        let radix_prefixed = self.peek(0) == Some('0')
            && matches!(self.peek(1), Some('x') | Some('X') | Some('b') | Some('o'));
        if radix_prefixed {
            self.i += 2;
            while self
                .peek(0)
                .map(|c| c.is_ascii_alphanumeric() || c == '_')
                .unwrap_or(false)
            {
                self.i += 1;
            }
        } else {
            while self.peek(0).map(|c| c.is_ascii_digit() || c == '_').unwrap_or(false) {
                self.i += 1;
            }
            // fraction: `.` followed by a digit (never `..` or a method call)
            if self.peek(0) == Some('.')
                && self.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false)
            {
                is_float = true;
                self.i += 1;
                while self.peek(0).map(|c| c.is_ascii_digit() || c == '_').unwrap_or(false) {
                    self.i += 1;
                }
            } else if self.peek(0) == Some('.')
                && !self.peek(1).map(|c| is_ident_start(c) || c == '.').unwrap_or(false)
            {
                // trailing-dot float `1.`
                is_float = true;
                self.i += 1;
            }
            // exponent
            if matches!(self.peek(0), Some('e') | Some('E')) {
                let mut j = 1;
                if matches!(self.peek(j), Some('+') | Some('-')) {
                    j += 1;
                }
                if self.peek(j).map(|c| c.is_ascii_digit()).unwrap_or(false) {
                    is_float = true;
                    self.i += j + 1;
                    while self
                        .peek(0)
                        .map(|c| c.is_ascii_digit() || c == '_')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                }
            }
            // type suffix (f32 / u64 / usize …)
            let sstart = self.i;
            while self.peek(0).map(is_ident_continue).unwrap_or(false) {
                self.i += 1;
            }
            let suffix = self.text(sstart, self.i);
            if suffix.starts_with("f32") || suffix.starts_with("f64") {
                is_float = true;
            }
        }
        let text = self.text(start, self.i);
        self.push(if is_float { Kind::Float } else { Kind::Int }, text, line);
    }
}

pub fn lex(src: &str) -> Lexed {
    let mut s = Scanner { cs: src.chars().collect(), i: 0, line: 1, out: Lexed::default() };
    while s.i < s.cs.len() {
        let c = s.cs[s.i];
        if c == '\n' {
            s.line += 1;
            s.i += 1;
            continue;
        }
        if c.is_whitespace() {
            s.i += 1;
            continue;
        }
        if c == '/' && s.peek(1) == Some('/') {
            let sl = s.line;
            let start = s.i + 2;
            while s.i < s.cs.len() && s.cs[s.i] != '\n' {
                s.i += 1;
            }
            let text = s.text(start, s.i);
            s.out.comments.push(Comment { start_line: sl, end_line: sl, text });
            continue;
        }
        if c == '/' && s.peek(1) == Some('*') {
            let sl = s.line;
            let start = s.i + 2;
            s.i += 2;
            let mut depth = 1usize;
            while s.i < s.cs.len() && depth > 0 {
                if s.cs[s.i] == '\n' {
                    s.line += 1;
                    s.i += 1;
                } else if s.cs[s.i] == '/' && s.peek(1) == Some('*') {
                    depth += 1;
                    s.i += 2;
                } else if s.cs[s.i] == '*' && s.peek(1) == Some('/') {
                    depth -= 1;
                    s.i += 2;
                } else {
                    s.i += 1;
                }
            }
            let text = s.text(start, s.i);
            let (sl2, el) = (sl, s.line);
            s.out.comments.push(Comment { start_line: sl2, end_line: el, text });
            continue;
        }
        if c == '"' {
            s.scan_plain_string();
            continue;
        }
        if (c == 'r' || c == 'b') && s.try_prefixed() {
            continue;
        }
        if is_ident_start(c) {
            let start = s.i;
            while s.peek(0).map(is_ident_continue).unwrap_or(false) {
                s.i += 1;
            }
            let text = s.text(start, s.i);
            let line = s.line;
            s.push(Kind::Ident, text, line);
            continue;
        }
        if c == '\'' {
            s.scan_char_or_lifetime();
            continue;
        }
        if c.is_ascii_digit() {
            s.scan_number();
            continue;
        }
        let line = s.line;
        s.push(Kind::Punct, c.to_string(), line);
        s.i += 1;
    }
    s.out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn ranges_are_not_floats() {
        let ks = kinds("for i in 0..n { let x = 0.5; }");
        assert!(ks.contains(&(Kind::Int, "0".to_string())));
        assert!(ks.contains(&(Kind::Float, "0.5".to_string())));
    }

    #[test]
    fn float_suffix_and_exponent() {
        let ks = kinds("let a = 1f32; let b = 2e-3; let c = 0x1f; let d = 1.max(2);");
        assert!(ks.contains(&(Kind::Float, "1f32".to_string())));
        assert!(ks.contains(&(Kind::Float, "2e-3".to_string())));
        assert!(ks.contains(&(Kind::Int, "0x1f".to_string())));
        assert!(ks.contains(&(Kind::Int, "1".to_string())), "1.max(2) keeps 1 an int");
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ks = kinds("fn f<'a>(x: &'a str, c: char) { let y = 'z'; let e = '\\n'; }");
        assert!(ks.contains(&(Kind::Lifetime, "a".to_string())));
        assert!(ks.contains(&(Kind::Char, "z".to_string())));
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let lexed = lex("// has HashMap inside\nlet s = \"HashMap::new()\"; /* and\nwrapping_add */");
        assert!(!lexed.toks.iter().any(|t| t.kind == Kind::Ident && t.text == "HashMap"));
        assert!(lexed.comments.iter().any(|c| c.text.contains("HashMap")));
        assert!(lexed
            .comments
            .iter()
            .any(|c| c.start_line == 2 && c.end_line == 3 && c.text.contains("wrapping_add")));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let ks = kinds("let s = r#\"unsafe { }\"#; let r#fn = 1;");
        assert!(!ks.iter().any(|(k, t)| *k == Kind::Ident && t == "unsafe"));
        assert!(ks.contains(&(Kind::Ident, "fn".to_string())));
    }

    #[test]
    fn nested_block_comments() {
        let lexed = lex("/* outer /* inner */ still comment */ let x = 1;");
        assert!(lexed.toks.iter().any(|t| t.is_ident("let")));
        assert_eq!(lexed.comments.len(), 1);
    }
}
