//! CLI for agn-lint. See lib.rs (and README §Determinism contract) for the
//! rule set.

use std::path::PathBuf;
use std::process::ExitCode;

use agn_lint::diag::{render_human, render_json};
use agn_lint::driver;
use agn_lint::policy::Policy;

const USAGE: &str = "\
agn-lint — determinism/unsafety contract linter (rules AGN-D1..D7)

USAGE:
    agn-lint [FLAGS] PATH...

PATHS are .rs files or directories (rust/src for the production tree).

FLAGS:
    --deny            exit 1 if any diagnostic is produced (CI gate mode)
    --json            print the JSON report instead of human file:line lines
    --manifest PATH   Cargo.toml checked under the dependency policy
                      (AGN-D7); default: discovered next to each scan root
    --no-dep-check    skip AGN-D7 entirely
    -h, --help        this text

EXIT CODES: 0 clean (or advisory mode), 1 violations under --deny, 2 usage
or I/O error.

Each rule's rationale lives in README.md §Determinism contract; waive a
single finding in place with `// lint:allow(AGN-Dn) <reason>`.";

struct Args {
    deny: bool,
    json: bool,
    dep_check: bool,
    manifest: Option<PathBuf>,
    roots: Vec<PathBuf>,
}

fn parse_args(argv: impl Iterator<Item = String>) -> Result<Args, String> {
    let mut args =
        Args { deny: false, json: false, dep_check: true, manifest: None, roots: Vec::new() };
    let mut it = argv;
    while let Some(a) = it.next() {
        match a.as_str() {
            "--deny" => args.deny = true,
            "--json" => args.json = true,
            "--no-dep-check" => args.dep_check = false,
            "--manifest" => {
                let p = it.next().ok_or("--manifest needs a path argument")?;
                args.manifest = Some(PathBuf::from(p));
            }
            "-h" | "--help" => return Err(String::new()),
            flag if flag.starts_with('-') => {
                return Err(format!("unknown flag: {flag}"));
            }
            path => args.roots.push(PathBuf::from(path)),
        }
    }
    if args.roots.is_empty() {
        return Err("no scan paths given (try: agn-lint --deny rust/src)".to_string());
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(msg) => {
            if msg.is_empty() {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            eprintln!("agn-lint: {msg}\n\n{USAGE}");
            return ExitCode::from(2);
        }
    };

    let mut manifests: Vec<PathBuf> = Vec::new();
    if args.dep_check {
        if let Some(m) = &args.manifest {
            manifests.push(m.clone());
        } else {
            for root in &args.roots {
                if let Some(m) = driver::discover_manifest(root) {
                    manifests.push(m);
                }
            }
            manifests.sort();
            manifests.dedup();
        }
    }

    let policy = Policy::production();
    let report = match driver::run(&args.roots, &manifests, &policy) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("agn-lint: {msg}");
            return ExitCode::from(2);
        }
    };

    if args.json {
        print!("{}", render_json(&report.diags, report.files_checked));
    } else if report.diags.is_empty() {
        println!(
            "agn-lint: clean ({} files checked, rules AGN-D1..D7)",
            report.files_checked
        );
    } else {
        print!("{}", render_human(&report.diags));
        eprintln!(
            "agn-lint: {} violation(s) across {} file(s) checked",
            report.diags.len(),
            report.files_checked
        );
    }

    if args.deny && !report.diags.is_empty() {
        return ExitCode::from(1);
    }
    ExitCode::SUCCESS
}
