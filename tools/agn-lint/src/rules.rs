//! The determinism/unsafety contract rules (AGN-D1..D6) over one source
//! file. AGN-D7 (dependency policy) lives in [`crate::deps`].
//!
//! Scope discipline shared by every rule:
//! - `#[cfg(test)]` / `#[cfg(loom)]` / `#[cfg(miri)]` items are exempt
//!   (tests may iterate hash maps or read clocks freely; the contract is
//!   about shipped lib code). `#[cfg(not(...))]` stays in scope.
//! - A diagnostic can be waived in place with
//!   `// lint:allow(AGN-Dn) <reason>` on the offending line or the line
//!   above; the reason is mandatory.

use std::collections::{BTreeMap, BTreeSet};

use crate::diag::Diag;
use crate::lexer::{lex, Kind, Lexed, Tok};
use crate::policy::{allowed, Policy};

/// Methods whose call on a hash collection observes iteration order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Nondeterminism-source identifiers banned outside approved boundaries.
const NONDET_IDENTS: &[&str] = &["SystemTime", "RandomState", "thread_rng", "from_entropy"];

pub fn check_source(display_path: &str, rel: &str, src: &str, policy: &Policy) -> Vec<Diag> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let off = inactive_mask(toks);
    let mut diags: Vec<Diag> = Vec::new();

    d1_hash_iteration(display_path, rel, toks, &off, policy, &mut diags);
    d2_wrapping(display_path, rel, toks, &off, policy, &mut diags);
    d3_unsafe(display_path, rel, toks, &off, &lexed, policy, &mut diags);
    d4_nondeterminism(display_path, rel, toks, &off, policy, &mut diags);
    d5_float_reduction(display_path, rel, toks, &off, policy, &mut diags);
    d6_naked_allow(display_path, toks, &off, &lexed, src, &mut diags);

    // In-place waivers, then dedupe to one diagnostic per (rule, line).
    diags.retain(|d| !waived(&lexed, d));
    let mut seen: BTreeSet<(&'static str, u32)> = BTreeSet::new();
    diags.retain(|d| seen.insert((d.rule, d.line)));
    diags
}

/// `// lint:allow(AGN-Dn[,AGN-Dm]) reason` on the diagnostic's line or the
/// line above waives it; an empty reason does not count.
fn waived(lexed: &Lexed, d: &Diag) -> bool {
    let lo = d.line.saturating_sub(1);
    for c in lexed.comments.iter().filter(|c| c.start_line <= d.line && c.end_line >= lo) {
        let Some(pos) = c.text.find("lint:allow(") else { continue };
        let rest = &c.text[pos + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let ids = &rest[..close];
        let reason = rest[close + 1..].trim_start_matches([':', '-']).trim();
        if !reason.is_empty() && ids.split(',').any(|id| id.trim() == d.rule) {
            return true;
        }
    }
    false
}

fn push(
    diags: &mut Vec<Diag>,
    file: &str,
    line: u32,
    rule: &'static str,
    message: impl Into<String>,
) {
    diags.push(Diag { file: file.to_string(), line, rule, message: message.into() });
}

// ---------------------------------------------------------------------------
// cfg(test)/cfg(loom) exemption regions
// ---------------------------------------------------------------------------

/// Token mask: true = token sits in a `#[cfg(test)]`-style item and is
/// exempt from every rule.
fn inactive_mask(toks: &[Tok]) -> Vec<bool> {
    let mut off = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        match match_cfg_attr(toks, i) {
            Some((after, inner, exempt)) => {
                if exempt && inner {
                    // #![cfg(test)] — the whole remaining file is exempt
                    for slot in off.iter_mut().skip(i) {
                        *slot = true;
                    }
                    return off;
                }
                if exempt {
                    let end = item_end(toks, after);
                    for slot in off.iter_mut().take(end + 1).skip(i) {
                        *slot = true;
                    }
                    i = end + 1;
                } else {
                    i = after;
                }
            }
            None => i += 1,
        }
    }
    off
}

/// If `toks[i..]` starts a `#[cfg(...)]` / `#![cfg(...)]` attribute, return
/// (index after the closing `]`, was-inner, gates-an-exempt-cfg).
fn match_cfg_attr(toks: &[Tok], i: usize) -> Option<(usize, bool, bool)> {
    if !toks.get(i)?.is_punct('#') {
        return None;
    }
    let mut j = i + 1;
    let inner = toks.get(j)?.is_punct('!');
    if inner {
        j += 1;
    }
    if !toks.get(j)?.is_punct('[') {
        return None;
    }
    if !toks.get(j + 1)?.is_ident("cfg") {
        return None;
    }
    if !toks.get(j + 2)?.is_punct('(') {
        return None;
    }
    let mut depth = 1i32;
    let mut k = j + 3;
    let mut negated = false;
    let mut exempt_word = false;
    while k < toks.len() && depth > 0 {
        let t = &toks[k];
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
        } else if t.kind == Kind::Ident {
            match t.text.as_str() {
                "not" => negated = true,
                "test" | "loom" | "miri" => exempt_word = true,
                _ => {}
            }
        }
        k += 1;
    }
    // expect the closing `]`
    if !toks.get(k).map(|t| t.is_punct(']')).unwrap_or(false) {
        return None;
    }
    Some((k + 1, inner, exempt_word && !negated))
}

/// Index of the last token of the item starting at `toks[i]` (after any
/// further attributes): either the matching `}` of its body or the `;` that
/// ends a body-less item.
fn item_end(toks: &[Tok], mut i: usize) -> usize {
    // skip stacked attributes
    while i + 1 < toks.len() && toks[i].is_punct('#') && toks[i + 1].is_punct('[') {
        let mut depth = 0i32;
        let mut j = i + 1;
        while j < toks.len() {
            if toks[j].is_punct('[') {
                depth += 1;
            } else if toks[j].is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j += 1;
        }
        i = (j + 1).min(toks.len());
    }
    let mut depth = 0i32;
    let mut in_brace_body = false;
    let mut k = i;
    while k < toks.len() {
        let t = &toks[k];
        if t.is_punct('{') {
            if depth == 0 {
                in_brace_body = true;
            }
            depth += 1;
        } else if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct('}') || t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
            if depth == 0 && in_brace_body && t.is_punct('}') {
                return k;
            }
        } else if t.is_punct(';') && depth == 0 {
            return k;
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}

// ---------------------------------------------------------------------------
// AGN-D1 — no hash-collection iteration in lib code
// ---------------------------------------------------------------------------

fn is_hash_ty(t: &Tok) -> bool {
    t.is_ident("HashMap") || t.is_ident("HashSet")
}

fn d1_hash_iteration(
    file: &str,
    rel: &str,
    toks: &[Tok],
    off: &[bool],
    policy: &Policy,
    diags: &mut Vec<Diag>,
) {
    if allowed(policy.d1_hash_iteration, rel) {
        return;
    }
    // Pass 1: names bound to a hash-collection type in this file, via
    // `name: …HashMap<…>` annotations (fields, params, lets) and
    // `let name = HashMap::new()`-style initializers.
    let mut hashy: BTreeMap<String, u32> = BTreeMap::new();
    for i in 0..toks.len() {
        if off[i] || toks[i].kind != Kind::Ident {
            continue;
        }
        // `name : <type window containing HashMap>`
        if toks.get(i + 1).map(|t| t.is_punct(':')).unwrap_or(false)
            && !toks.get(i + 2).map(|t| t.is_punct(':')).unwrap_or(false)
        {
            let mut depth = 0i32;
            for j in i + 2..(i + 42).min(toks.len()) {
                let t = &toks[j];
                if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                    depth += 1;
                } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                    if depth == 0 {
                        break;
                    }
                    depth -= 1;
                } else if depth == 0 && (t.is_punct(',') || t.is_punct(';') || t.is_punct('=')) {
                    break;
                } else if is_hash_ty(t) {
                    hashy.entry(toks[i].text.clone()).or_insert(toks[i].line);
                    break;
                }
            }
        }
        // `let [mut] name = HashMap::…` / `= HashSet::…`
        if toks[i].is_ident("let") {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.is_ident("mut")).unwrap_or(false) {
                j += 1;
            }
            if toks.get(j).map(|t| t.kind == Kind::Ident).unwrap_or(false)
                && toks.get(j + 1).map(|t| t.is_punct('=')).unwrap_or(false)
                && toks.get(j + 2).map(is_hash_ty).unwrap_or(false)
            {
                hashy.entry(toks[j].text.clone()).or_insert(toks[j].line);
            }
        }
    }
    if hashy.is_empty() {
        return;
    }
    let msg = |name: &str| {
        format!(
            "iteration over hash collection `{name}` observes RandomState order; \
             use BTreeMap/BTreeSet or sort before iterating"
        )
    };
    for i in 0..toks.len() {
        if off[i] {
            continue;
        }
        // receiver.method( where receiver is hashy and method observes order
        if toks[i].kind == Kind::Ident
            && hashy.contains_key(&toks[i].text)
            && toks.get(i + 1).map(|t| t.is_punct('.')).unwrap_or(false)
        {
            if let Some(m) = toks.get(i + 2) {
                if ITER_METHODS.contains(&m.text.as_str())
                    && toks.get(i + 3).map(|t| t.is_punct('(')).unwrap_or(false)
                {
                    push(diags, file, toks[i].line, "AGN-D1", msg(&toks[i].text));
                }
            }
        }
        // `for pat in <expr mentioning a hashy name> {`
        if toks[i].is_ident("for") {
            let mut j = i + 1;
            let mut found_in = None;
            while j < toks.len() && j < i + 40 {
                if toks[j].is_ident("in") {
                    found_in = Some(j);
                    break;
                }
                if toks[j].is_punct('{') || toks[j].is_punct(';') {
                    break;
                }
                j += 1;
            }
            if let Some(in_idx) = found_in {
                let mut depth = 0i32;
                for k in in_idx + 1..(in_idx + 60).min(toks.len()) {
                    let t = &toks[k];
                    if t.is_punct('(') || t.is_punct('[') {
                        depth += 1;
                    } else if t.is_punct(')') || t.is_punct(']') {
                        depth -= 1;
                    } else if t.is_punct('{') && depth == 0 {
                        break;
                    } else if t.kind == Kind::Ident && hashy.contains_key(&t.text) {
                        push(diags, file, t.line, "AGN-D1", msg(&t.text));
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AGN-D2 — wrapping arithmetic confined to the modeled-wraparound domain
// ---------------------------------------------------------------------------

fn d2_wrapping(
    file: &str,
    rel: &str,
    toks: &[Tok],
    off: &[bool],
    policy: &Policy,
    diags: &mut Vec<Diag>,
) {
    if allowed(policy.d2_wrapping, rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if off[i] || t.kind != Kind::Ident {
            continue;
        }
        if t.text.starts_with("wrapping_") || t.text == "Wrapping" {
            push(
                diags,
                file,
                t.line,
                "AGN-D2",
                format!(
                    "`{}` outside the modeled-wraparound domain (compute::lut / util::rng / \
                     util::fnv); wraparound elsewhere is a masked bug, not a model",
                    t.text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// AGN-D3 — unsafe requires an allowlisted module and a SAFETY comment
// ---------------------------------------------------------------------------

fn d3_unsafe(
    file: &str,
    rel: &str,
    toks: &[Tok],
    off: &[bool],
    lexed: &Lexed,
    policy: &Policy,
    diags: &mut Vec<Diag>,
) {
    for (i, t) in toks.iter().enumerate() {
        if off[i] || !t.is_ident("unsafe") {
            continue;
        }
        if !allowed(policy.d3_unsafe, rel) {
            push(
                diags,
                file,
                t.line,
                "AGN-D3",
                "`unsafe` outside the allowlisted kernel modules (compute::simd); \
                 widen the policy deliberately or keep the code safe",
            );
        }
        if !lexed.comment_in_range_contains(t.line.saturating_sub(3), t.line, "SAFETY:") {
            push(
                diags,
                file,
                t.line,
                "AGN-D3",
                "`unsafe` without a `// SAFETY:` comment in the preceding 3 lines",
            );
        }
    }
}

// ---------------------------------------------------------------------------
// AGN-D4 — ambient nondeterminism sources
// ---------------------------------------------------------------------------

fn d4_nondeterminism(
    file: &str,
    rel: &str,
    toks: &[Tok],
    off: &[bool],
    policy: &Policy,
    diags: &mut Vec<Diag>,
) {
    if allowed(policy.d4_nondeterminism, rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if off[i] || t.kind != Kind::Ident {
            continue;
        }
        // `std::env` paths (env::var & friends). `std::env::args[_os]` is
        // exempt: argv is an input, not ambient state.
        if t.is_ident("std")
            && toks.get(i + 1).map(|x| x.is_punct(':')).unwrap_or(false)
            && toks.get(i + 2).map(|x| x.is_punct(':')).unwrap_or(false)
            && toks.get(i + 3).map(|x| x.is_ident("env")).unwrap_or(false)
        {
            let is_args = toks.get(i + 4).map(|x| x.is_punct(':')).unwrap_or(false)
                && toks.get(i + 5).map(|x| x.is_punct(':')).unwrap_or(false)
                && toks
                    .get(i + 6)
                    .map(|x| x.is_ident("args") || x.is_ident("args_os"))
                    .unwrap_or(false);
            if !is_args {
                push(
                    diags,
                    file,
                    t.line,
                    "AGN-D4",
                    "ambient environment read outside util::env (the one approved \
                     boundary); route it through util::env::read",
                );
            }
        }
        if NONDET_IDENTS.contains(&t.text.as_str()) {
            push(
                diags,
                file,
                t.line,
                "AGN-D4",
                format!(
                    "`{}` is a nondeterminism source; the contract allows wall-clock \
                     and entropy only inside util::timer / benchkit / util::env",
                    t.text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// AGN-D5 — float reductions confined to compute:: (order-pinned)
// ---------------------------------------------------------------------------

fn d5_float_reduction(
    file: &str,
    rel: &str,
    toks: &[Tok],
    off: &[bool],
    policy: &Policy,
    diags: &mut Vec<Diag>,
) {
    if allowed(policy.d5_float_reduction, rel) {
        return;
    }
    for i in 0..toks.len() {
        if off[i] || toks[i].kind != Kind::Ident {
            continue;
        }
        let name = toks[i].text.as_str();
        if !(name == "sum" || name == "product" || name == "fold") {
            continue;
        }
        let after_dot = i
            .checked_sub(1)
            .and_then(|p| toks.get(p))
            .map(|t| t.is_punct('.'))
            .unwrap_or(false);
        if !after_dot {
            continue;
        }
        let (lo, hi) = stmt_window(toks, i);
        let float_involved = toks[lo..hi].iter().any(|t| {
            t.kind == Kind::Float || t.is_ident("f32") || t.is_ident("f64")
        });
        if float_involved {
            push(
                diags,
                file,
                toks[i].line,
                "AGN-D5",
                format!(
                    "float `.{name}()` reduction outside compute:: — summation order \
                     must be pinned; use compute::reduce (sum_f32/sum_f64/fold_*)"
                ),
            );
        }
    }
}

/// The statement-ish token window around `i`: bounded by `;`/`,`/braces at
/// the same nesting level (commas inside nested parens/brackets do not
/// split, so closure arguments and struct-literal fields stay intact).
fn stmt_window(toks: &[Tok], i: usize) -> (usize, usize) {
    let mut lo = 0usize;
    let mut depth = 0i32;
    for k in (0..i).rev() {
        let t = &toks[k];
        if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth += 1;
        } else if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            if depth == 0 {
                lo = k + 1;
                break;
            }
            depth -= 1;
        } else if depth == 0 && (t.is_punct(';') || t.is_punct(',')) {
            lo = k + 1;
            break;
        }
    }
    let mut hi = toks.len();
    depth = 0;
    for (k, t) in toks.iter().enumerate().skip(i) {
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            if depth == 0 {
                hi = k;
                break;
            }
            depth -= 1;
        } else if depth == 0 && (t.is_punct(';') || t.is_punct(',')) {
            hi = k;
            break;
        }
    }
    (lo, hi)
}

// ---------------------------------------------------------------------------
// AGN-D6 — #[allow(...)] requires an invariant comment
// ---------------------------------------------------------------------------

fn d6_naked_allow(
    file: &str,
    toks: &[Tok],
    off: &[bool],
    lexed: &Lexed,
    src: &str,
    diags: &mut Vec<Diag>,
) {
    let lines: Vec<&str> = src.lines().collect();
    let commented: BTreeSet<u32> = lexed
        .comments
        .iter()
        .flat_map(|c| c.start_line..=c.end_line)
        .collect();
    for i in 0..toks.len() {
        if off[i] || !toks[i].is_punct('#') {
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).map(|t| t.is_punct('!')).unwrap_or(false) {
            j += 1;
        }
        if !toks.get(j).map(|t| t.is_punct('[')).unwrap_or(false) {
            continue;
        }
        if !toks.get(j + 1).map(|t| t.is_ident("allow")).unwrap_or(false) {
            continue;
        }
        let line = toks[i].line;
        if commented.contains(&line) {
            continue; // trailing `// why` on the attribute line
        }
        // walk up through any attribute-only lines to the justification
        let mut l = line.saturating_sub(1);
        let mut justified = false;
        while l >= 1 {
            if commented.contains(&l) {
                justified = true;
                break;
            }
            let text = lines.get((l - 1) as usize).map(|s| s.trim()).unwrap_or("");
            if text.starts_with("#[") || text.starts_with("#![") {
                l -= 1;
                continue;
            }
            break;
        }
        if !justified {
            push(
                diags,
                file,
                line,
                "AGN-D6",
                "#[allow(...)] without an invariant comment explaining why the \
                 lint does not apply here",
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Policy;

    fn run(src: &str) -> Vec<Diag> {
        check_source("t.rs", "t.rs", src, &Policy::empty())
    }

    fn rules(src: &str) -> Vec<&'static str> {
        run(src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn d1_iteration_flagged_keyed_lookup_clean() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<String, u64>) -> u64 {\n\
                       let mut t = 0;\n\
                       for (_k, v) in m.iter() { t += v; }\n\
                       t + m.get(\"x\").copied().unwrap_or(0)\n\
                   }\n";
        let ds = run(src);
        assert_eq!(ds.iter().filter(|d| d.rule == "AGN-D1").count(), 1);
        assert_eq!(ds[0].line, 4);
        let keyed = "use std::collections::HashMap;\n\
                     fn g(m: &HashMap<String, u64>) -> bool { m.contains_key(\"x\") }\n";
        assert!(run(keyed).is_empty());
    }

    #[test]
    fn d2_wrapping_and_waiver() {
        assert_eq!(rules("fn f(a: u64) -> u64 { a.wrapping_mul(3) }"), vec!["AGN-D2"]);
        let waived = "fn f(a: u64) -> u64 {\n\
                      // lint:allow(AGN-D2) fixture models mod-2^64 arithmetic\n\
                      a.wrapping_mul(3)\n}";
        assert!(run(waived).is_empty());
        let no_reason = "fn f(a: u64) -> u64 {\n// lint:allow(AGN-D2)\na.wrapping_mul(3)\n}";
        assert_eq!(rules(no_reason), vec!["AGN-D2"]);
    }

    #[test]
    fn d3_both_halves() {
        let both = "fn f(x: &[u8]) -> u8 { unsafe { *x.get_unchecked(0) } }";
        assert_eq!(rules(both), vec!["AGN-D3"]); // deduped to one per line
        let with_comment = "// SAFETY: caller guarantees non-empty\n\
                            fn f(x: &[u8]) -> u8 { unsafe { *x.get_unchecked(0) } }";
        let ds = run(with_comment);
        assert_eq!(ds.len(), 1);
        assert!(ds[0].message.contains("allowlisted"));
    }

    #[test]
    fn d4_env_flagged_args_exempt() {
        assert_eq!(rules("fn f() { let _ = std::env::var(\"X\"); }"), vec!["AGN-D4"]);
        assert!(run("fn f() { let _ = std::env::args().count(); }").is_empty());
        assert_eq!(rules("fn f() { let _ = std::time::SystemTime::now(); }"), vec!["AGN-D4"]);
    }

    #[test]
    fn d5_float_only() {
        assert_eq!(rules("fn f(x: &[f32]) -> f32 { x.iter().sum::<f32>() }"), vec!["AGN-D5"]);
        assert_eq!(
            rules("fn f(x: &[f64]) -> f64 { x.iter().fold(0.0, |a, b| a.max(*b)) }"),
            vec!["AGN-D5"]
        );
        assert!(run("fn f(x: &[usize]) -> usize { x.iter().sum() }").is_empty());
        assert!(run("fn f(x: &[Vec<u8>]) -> usize { x.iter().map(|v| v.len()).sum() }")
            .is_empty());
    }

    #[test]
    fn d5_struct_literal_fields_do_not_leak_floats() {
        // the float in a neighbouring field must not taint the integer sum
        let src = "struct S { a: f64, b: usize }\n\
                   fn f(xs: &[usize]) -> S {\n\
                       S { a: 0.5, b: xs.iter().sum() }\n\
                   }\n";
        assert!(run(src).is_empty());
    }

    #[test]
    fn d6_justification_forms() {
        assert_eq!(rules("#[allow(dead_code)]\nfn f() {}"), vec!["AGN-D6"]);
        assert!(run("// invariant: exercised via ffi\n#[allow(dead_code)]\nfn f() {}")
            .is_empty());
        assert!(run("#[allow(dead_code)] // invariant: ffi entry\nfn f() {}").is_empty());
        assert!(run("/// docs count as justification\n#[allow(dead_code)]\nfn f() {}")
            .is_empty());
        // attributes stack: comment above the stack still counts
        assert!(run("// why\n#[allow(dead_code)]\n#[allow(unused)]\nfn f() {}").is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn f(x: &[f32]) -> f32 { x.iter().sum::<f32>() }\n\
                   fn g(a: u64) -> u64 { a.wrapping_add(1) }\n}\n";
        assert!(run(src).is_empty());
        let not_gated = "#[cfg(not(loom))]\nfn g(a: u64) -> u64 { a.wrapping_add(1) }\n";
        assert_eq!(rules(not_gated), vec!["AGN-D2"]);
    }
}
