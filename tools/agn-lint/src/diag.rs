//! Diagnostics and their renderings (human `file:line` lines and the JSON
//! report consumed by CI and the golden snapshot test).

/// One finding. The derived `Ord` gives the report order the contract
/// promises: (file, line, rule).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

pub fn render_human(diags: &[Diag]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.message));
    }
    out
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine-readable report. Deterministic byte-for-byte for a
/// given diagnostic set (keys in fixed order, diags pre-sorted by the
/// caller).
pub fn render_json(diags: &[Diag], files_checked: usize) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"tool\": \"agn-lint\",\n");
    out.push_str("  \"version\": 1,\n");
    out.push_str(&format!("  \"files_checked\": {files_checked},\n"));
    out.push_str(&format!("  \"violations\": {},\n", diags.len()));
    out.push_str("  \"diagnostics\": [");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            escape_json(d.rule),
            escape_json(&d.file),
            d.line,
            escape_json(&d.message)
        ));
    }
    if !diags.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_and_sorts_stably() {
        let mut ds = vec![
            Diag { file: "b.rs".into(), line: 2, rule: "AGN-D2", message: "x\"y".into() },
            Diag { file: "a.rs".into(), line: 9, rule: "AGN-D1", message: "m".into() },
            Diag { file: "b.rs".into(), line: 2, rule: "AGN-D1", message: "m".into() },
        ];
        ds.sort();
        assert_eq!(ds[0].file, "a.rs");
        assert_eq!(ds[1].rule, "AGN-D1");
        let j = render_json(&ds, 3);
        assert!(j.contains("\\\"y"));
        assert!(j.contains("\"violations\": 3"));
    }

    #[test]
    fn empty_report_is_compact() {
        let j = render_json(&[], 5);
        assert!(j.contains("\"diagnostics\": []"));
    }
}
