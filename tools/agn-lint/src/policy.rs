//! Module allowlists for the determinism contract (README §Determinism
//! contract). Paths are `src/`-relative with `/` separators; an entry
//! ending in `/` is a prefix (whole subtree), otherwise it must match the
//! file exactly.

pub struct Policy {
    /// AGN-D1: modules allowed to *iterate* std hash collections (keyed
    /// lookup is allowed everywhere). Empty by design: iterated state is
    /// `BTreeMap`/`BTreeSet` in this tree.
    pub d1_hash_iteration: &'static [&'static str],
    /// AGN-D2: the modeled-wraparound domain — modules where `wrapping_*`
    /// arithmetic is the *specification* (LUT i32 accumulation, PCG32
    /// stream, FNV-1a digests), not an accident.
    pub d2_wrapping: &'static [&'static str],
    /// AGN-D3: modules allowed to contain `unsafe` at all (each block
    /// still needs a `// SAFETY:` comment). `compute/simd/` holds the
    /// `std::arch` kernel tiers (AVX2/NEON gathers and axpy) — the only
    /// unsafe in the tree.
    pub d3_unsafe: &'static [&'static str],
    /// AGN-D4: approved ambient-input boundaries. `util/env.rs` is the one
    /// place that touches `std::env::var`; timer/benchkit are approved
    /// measurement boundaries (they read clocks and the bench budget).
    pub d4_nondeterminism: &'static [&'static str],
    /// AGN-D5: modules where float reduction order is pinned by
    /// construction (serial-equivalent kernels and the order-pinned
    /// `compute::reduce` helpers).
    pub d5_float_reduction: &'static [&'static str],
}

impl Policy {
    /// The production policy for `rust/src`.
    pub fn production() -> Policy {
        Policy {
            d1_hash_iteration: &[],
            d2_wrapping: &["compute/lut.rs", "util/rng.rs", "util/fnv.rs"],
            d3_unsafe: &["compute/simd/"],
            d4_nondeterminism: &["util/env.rs", "util/timer.rs", "benchkit.rs"],
            d5_float_reduction: &["compute/"],
        }
    }

    /// An empty policy (nothing allowlisted) — used by the fixture
    /// self-tests so fixtures exercise each rule without path games.
    pub fn empty() -> Policy {
        Policy {
            d1_hash_iteration: &[],
            d2_wrapping: &[],
            d3_unsafe: &[],
            d4_nondeterminism: &[],
            d5_float_reduction: &[],
        }
    }
}

/// True if `rel` (a `src/`-relative path) matches an allowlist.
pub fn allowed(list: &[&str], rel: &str) -> bool {
    list.iter().any(|e| {
        if let Some(prefix) = e.strip_suffix('/') {
            rel.starts_with(prefix) && rel[prefix.len()..].starts_with('/')
        } else {
            rel == *e
        }
    })
}

/// Normalize `path` to the `src/`-relative form the allowlists use: strip
/// everything up to and including the last `/src/` component (so the tool
/// behaves identically whatever directory it is invoked from); otherwise
/// strip a leading `./`.
pub fn module_rel(path: &str) -> String {
    let norm = path.replace('\\', "/");
    if let Some(pos) = norm.rfind("/src/") {
        return norm[pos + "/src/".len()..].to_string();
    }
    if let Some(stripped) = norm.strip_prefix("src/") {
        return stripped.to_string();
    }
    norm.strip_prefix("./").unwrap_or(&norm).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_paths_strip_src() {
        assert_eq!(module_rel("rust/src/compute/lut.rs"), "compute/lut.rs");
        assert_eq!(module_rel("/abs/repo/rust/src/util/rng.rs"), "util/rng.rs");
        assert_eq!(module_rel("fixtures/bad/d1.rs"), "fixtures/bad/d1.rs");
    }

    #[test]
    fn prefix_and_exact_matching() {
        assert!(allowed(&["compute/"], "compute/reduce.rs"));
        assert!(allowed(&["compute/"], "compute/simd/avx2.rs"));
        assert!(!allowed(&["compute/"], "computegemm.rs"));
        assert!(allowed(&["benchkit.rs"], "benchkit.rs"));
        assert!(!allowed(&["benchkit.rs"], "util/benchkit.rs"));
    }
}
