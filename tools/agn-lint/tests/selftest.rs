//! Fixture-corpus self-tests: every bad fixture trips exactly its rule ID,
//! every good twin passes, the JSON report matches the golden snapshot
//! byte-for-byte, and the binary's `--deny` exit codes hold end-to-end.
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use std::path::PathBuf;

use agn_lint::deps;
use agn_lint::diag::{render_json, Diag};
use agn_lint::driver;
use agn_lint::policy::{module_rel, Policy};
use agn_lint::rules;

fn fixture_root(sub: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(sub)
}

fn check_file(dir: &str, name: &str) -> Vec<Diag> {
    let path = fixture_root(dir).join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let disp = path.to_string_lossy().replace('\\', "/");
    rules::check_source(name, &module_rel(&disp), &src, &Policy::production())
}

#[test]
fn every_bad_fixture_trips_exactly_its_rule() {
    let cases = [
        ("d1_hash_iteration.rs", "AGN-D1"),
        ("d2_wrapping.rs", "AGN-D2"),
        ("d3_unsafe.rs", "AGN-D3"),
        ("d4_env.rs", "AGN-D4"),
        ("d5_float_sum.rs", "AGN-D5"),
        ("d6_allow.rs", "AGN-D6"),
        // nested under src/ so module_rel lands inside the compute/simd/
        // allowlist: only the missing-SAFETY half of AGN-D3 fires
        ("src/compute/simd/d3_missing_safety.rs", "AGN-D3"),
    ];
    for (file, rule) in cases {
        let ds = check_file("bad", file);
        assert_eq!(ds.len(), 1, "{file} must trip exactly once: {ds:?}");
        assert_eq!(ds[0].rule, rule, "{file} tripped the wrong rule: {ds:?}");
    }
}

#[test]
fn bad_manifest_trips_d7() {
    let path = fixture_root("bad").join("Cargo_bad.toml");
    let src = std::fs::read_to_string(path).unwrap();
    let ds = deps::check_manifest("Cargo_bad.toml", &src);
    assert_eq!(ds.len(), 1, "{ds:?}");
    assert_eq!(ds[0].rule, "AGN-D7");
    assert!(ds[0].message.contains("rand"));
}

/// Recursively collect `.rs` files (the corpus now nests `src/compute/simd`
/// twins for the path-sensitive AGN-D3 allowlist).
fn collect_rs(dir: &PathBuf, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir).unwrap().map(|e| e.unwrap().path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

#[test]
fn every_good_fixture_is_clean() {
    let dir = fixture_root("good");
    let mut saw = 0usize;
    let mut files = Vec::new();
    collect_rs(&dir, &mut files);
    for p in files {
        let name = p
            .strip_prefix(&dir)
            .unwrap()
            .to_string_lossy()
            .replace('\\', "/");
        let ds = check_file("good", &name);
        assert!(ds.is_empty(), "good fixture {name} must lint clean: {ds:?}");
        saw += 1;
    }
    assert!(saw >= 8, "good corpus unexpectedly small ({saw} files)");
    let m = dir.join("Cargo_good.toml");
    let ds = deps::check_manifest("Cargo_good.toml", &std::fs::read_to_string(m).unwrap());
    assert!(ds.is_empty(), "good manifest must pass AGN-D7: {ds:?}");
}

#[test]
fn golden_json_snapshot() {
    let bad = fixture_root("bad");
    let manifest = bad.join("Cargo_bad.toml");
    let report = driver::run(
        &[bad],
        std::slice::from_ref(&manifest),
        &Policy::production(),
    )
    .unwrap();
    // Strip the machine-specific prefix so the snapshot is portable.
    let mapped: Vec<Diag> = report
        .diags
        .into_iter()
        .map(|mut d| {
            if let Some(pos) = d.file.rfind("/fixtures/") {
                d.file = d.file[pos + "/fixtures/".len()..].to_string();
            }
            d
        })
        .collect();
    let json = render_json(&mapped, report.files_checked);
    let golden = include_str!("fixtures/golden_diagnostics.json");
    assert_eq!(
        json, golden,
        "JSON report drifted from tests/fixtures/golden_diagnostics.json; \
         update the snapshot deliberately if the change is intended"
    );
}

#[test]
fn deny_mode_exit_codes_and_json_rule_ids() {
    let exe = env!("CARGO_BIN_EXE_agn-lint");
    let bad = fixture_root("bad");
    let out = std::process::Command::new(exe)
        .arg("--deny")
        .arg("--json")
        .arg("--manifest")
        .arg(bad.join("Cargo_bad.toml"))
        .arg(&bad)
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1), "--deny must exit 1 on the bad corpus");
    let stdout = String::from_utf8(out.stdout).unwrap();
    for rule in ["AGN-D1", "AGN-D2", "AGN-D3", "AGN-D4", "AGN-D5", "AGN-D6", "AGN-D7"] {
        assert!(stdout.contains(rule), "JSON output is missing {rule}: {stdout}");
    }

    let good = fixture_root("good");
    let out = std::process::Command::new(exe)
        .arg("--deny")
        .arg("--manifest")
        .arg(good.join("Cargo_good.toml"))
        .arg(&good)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "good corpus must pass --deny: {}",
        String::from_utf8_lossy(&out.stdout)
    );
}
