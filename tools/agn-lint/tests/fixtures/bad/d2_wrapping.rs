// AGN-D2 bad twin: wraparound arithmetic outside the modeled domain.
pub fn mix(a: u64, b: u64) -> u64 {
    a.wrapping_mul(b).wrapping_add(17)
}
