// AGN-D1 bad twin: iterating a RandomState-seeded map in lib code.
use std::collections::HashMap;

pub fn report(m: &HashMap<String, u64>) -> u64 {
    let mut total = 0;
    for (_k, v) in m.iter() {
        total += v;
    }
    total
}
