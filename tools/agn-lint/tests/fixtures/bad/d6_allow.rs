// AGN-D6 bad twin: this banner is separated from the attribute by a
// blank line, so it does not count as a justification.

#[allow(dead_code)]
fn helper() {}
