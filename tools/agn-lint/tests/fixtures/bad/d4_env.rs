// AGN-D4 bad twin: ambient environment read outside util::env.
pub fn threads() -> usize {
    std::env::var("AGN_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
