// AGN-D3 bad twin: unsafe outside the allowlist. The SAFETY comment is
// present, so exactly the allowlist half of the rule fires.
pub fn first(xs: &[u8]) -> u8 {
    // SAFETY: callers pass non-empty slices (fixture pretext)
    unsafe { *xs.get_unchecked(0) }
}
