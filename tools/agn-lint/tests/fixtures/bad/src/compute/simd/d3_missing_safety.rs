//! Bad twin: an unsafe block inside the allowlisted kernel module but
//! without the mandatory safety comment in the 3-line window above it.

pub fn first(x: &[u8]) -> u8 {
    unsafe { *x.get_unchecked(0) }
}
