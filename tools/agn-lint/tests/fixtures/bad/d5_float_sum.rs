// AGN-D5 bad twin: unpinned float reduction outside compute::.
pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}
