// AGN-D3 good twin: the safe API expresses the same access.
pub fn first(xs: &[u8]) -> Option<u8> {
    xs.first().copied()
}
