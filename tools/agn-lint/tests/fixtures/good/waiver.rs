// One-off waivers carry the rule ID and a mandatory reason.
pub fn fixture_mix(seed: u64) -> u64 {
    // lint:allow(AGN-D2) fixture demonstrates the in-place waiver form
    seed.wrapping_add(0x9e3779b97f4a7c15)
}
