// AGN-D4 good twin: argv is an input, not ambient state — std::env::args
// is exempt; configuration otherwise arrives as parameters.
pub fn arg_count() -> usize {
    std::env::args().skip(1).count()
}

pub fn threads(configured: Option<usize>) -> usize {
    configured.unwrap_or(1)
}
