// AGN-D5 good twin: integer reductions are unaffected, and explicit
// left-to-right accumulation pins the float order.
pub fn count(xs: &[Vec<u8>]) -> usize {
    xs.iter().map(|v| v.len()).sum()
}

pub fn total(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}
