// Test modules are outside the contract: this file must lint clean.
pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use super::double;

    #[test]
    fn wrapping_and_float_sums_are_fine_in_tests() {
        let xs = [0.5f32, 1.5];
        let s: f32 = xs.iter().sum();
        assert!(s > 0.0);
        assert_eq!(double(2).wrapping_add(1), 5);
    }
}
