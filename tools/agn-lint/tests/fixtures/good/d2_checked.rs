// AGN-D2 good twin: bit mixing without modeled wraparound.
pub fn mix(a: u64, b: u64) -> u64 {
    a ^ b.rotate_left(13)
}
