//! Good twin: unsafe confined to the allowlisted kernel module, with the
//! mandatory justification comment within the 3-line lookback window.

pub fn first(x: &[u8]) -> u8 {
    assert!(!x.is_empty());
    // SAFETY: the assert above guarantees index 0 is in bounds.
    unsafe { *x.get_unchecked(0) }
}
