// AGN-D1 good twin: iterate ordered collections; keyed hash lookup is
// explicitly fine. (Names differ because the rule tracks hash-typed
// bindings per file, not per scope.)
use std::collections::{BTreeMap, HashMap};

pub fn lookup(index: &HashMap<String, u64>, k: &str) -> Option<u64> {
    index.get(k).copied()
}

pub fn report(ordered: &BTreeMap<String, u64>) -> u64 {
    let mut total = 0;
    for (_k, v) in ordered.iter() {
        total += v;
    }
    total
}
