// AGN-D6 good twin: both justification forms.
// invariant: helper is exercised only through the fixture corpus
#[allow(dead_code)]
fn helper() {}

#[allow(dead_code)] // invariant: kept for API parity with helper()
fn helper_too() {}
